PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify bench test

# Tier-1 verification (same command as ROADMAP.md / CI)
verify:
	$(PYTHON) -m pytest -x -q

# Full suite without fail-fast (CI uses this for complete reports)
test:
	$(PYTHON) -m pytest -q

bench:
	$(PYTHON) -m benchmarks.run
