PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify bench bench-json check-bench test tune lint lint-kernels

# Tier-1 verification (same command as ROADMAP.md / CI)
verify:
	$(PYTHON) -m pytest -x -q

# Full suite without fail-fast (CI uses this for complete reports)
test:
	$(PYTHON) -m pytest -q

bench:
	$(PYTHON) -m benchmarks.run

# Machine-readable perf trajectory: BENCH_<name>.json per bench.
# BENCH_ARGS narrows the set (CI smoke: BENCH_ARGS="--only ...").
BENCH_ARGS ?=
bench-json:
	$(PYTHON) -m benchmarks.run --json-dir results/bench $(BENCH_ARGS)

# The CI perf-story guard (run after bench-json): fused-vs-host traffic
# floor at every registered olm width, fresh bench JSON vs the committed
# results/baseline seeds, tuning.json schema + k_tile re-pin invariant.
check-bench:
	$(PYTHON) tools/check_bench.py

# Static analyzer (tools/olmlint.py): jaxpr kernel contracts + int32
# overflow proof + VMEM model + AST repo rules. ruff (style) runs only
# where installed — the dev container ships without it; CI installs it.
lint:
	$(PYTHON) tools/olmlint.py
	@command -v ruff >/dev/null 2>&1 \
		&& ruff check . \
		|| echo "ruff not installed; skipping style pass (CI runs it)"

# Kernel engine only (skips AST + ruff): the loop you run while
# editing a kernel body or a truncation schedule.
lint-kernels:
	$(PYTHON) tools/olmlint.py --engine kernels

# Populate the olm matmul tiling-autotuner cache (results/tuning.json)
# for the launch/shapes.py shape set. TUNE_ARGS passes CLI flags, e.g.
# TUNE_ARGS="--heuristic-only" to skip measurement.
TUNE_ARGS ?=
tune:
	$(PYTHON) -m repro.kernels.online_dot.tuning $(TUNE_ARGS)
