"""Transformer building blocks: norms, RoPE, GQA/SWA/cross attention, MLP.

Pure functions over explicit param pytrees (nested dicts of jax.Array).
Every matmul goes through core.numerics.DotEngine, so any registered
numerics mode — native, the truncated digit-plane matmul (tpmm), or the
fused online inner-product array (olm) — can be enabled per layer by
constructing the engine with that mode. Shapes use the convention
  x: (B, S, d_model)   q: (B, S, Hq, Dh)   kv: (B, S, Hkv, Dh)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.numerics import DotEngine
from repro.distributed.constraints import constrain, dp_axes
from .config import ModelConfig

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# rotary embeddings (standard full and chatglm-style half/2d)
# --------------------------------------------------------------------------

def rope_angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (..., S) -> cos/sin (..., S, dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, *, style: str, theta: float) -> jax.Array:
    """x (B, S, H, Dh). style 'full' rotates all dims; 'half' (chatglm 2d)
    rotates the first half of head dims and passes the rest through."""
    B, S, H, Dh = x.shape
    rot = Dh if style == "full" else Dh // 2
    cos, sin = rope_angles(positions, rot, theta)  # (B?, S, rot/2)
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(B, S, H, rot)
    if rot < Dh:
        out = jnp.concatenate([out, x[..., rot:].astype(jnp.float32)], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# paged KV cache plumbing (block pools + per-lane block tables)
# --------------------------------------------------------------------------
#
# A paged attention cache replaces the contiguous per-lane (B, T, H, D)
# ring with a per-layer block pool (num_blocks, block_size, H, D) plus a
# per-lane block table (B, max_blocks_per_lane) of pool indices, so KV
# memory scales with the pool size (live tokens) instead of B * max_len.
# Block id 0 is the permanently-reserved TRASH block: unowned table
# entries point at it, so padding rows and idle decode lanes write their
# garbage there instead of corrupting live lanes. View slot t of a lane
# holds absolute position t (block j covers positions [j*bs, (j+1)*bs)),
# exactly the contiguous layout, so causal masking makes the paged read
# bit-identical to the contiguous one. All pool reads/writes below are
# sequential dynamic_slice / dynamic_update_slice walks (no gather).

TRASH_BLOCK = 0


def paged_pool_write(pool, table, lane_pos, vals):
    """Write one decode step's k or v into the block pool.

    pool (NB, bs, H, D); table (B, MBL) int32; lane_pos (B,) absolute
    position each lane writes; vals (B, 1, H, D). Lanes whose table row
    is unowned (all TRASH_BLOCK) land in the trash block, and so does
    any out-of-range id (a corrupted table entry): dynamic_update_slice
    would otherwise clamp it to the last block — silently overwriting
    another lane's live KV instead of a sacrificial one.
    """
    NB, bs = pool.shape[0], pool.shape[1]
    table = jnp.where((table >= 0) & (table < NB), table, TRASH_BLOCK)
    blk = lane_pos // bs
    off = lane_pos - blk * bs

    def step(pl, x):
        row, b, o, val = x            # val (H, D) -> update (1, 1, H, D)
        bid = jax.lax.dynamic_slice(row, (b,), (1,))[0]
        z = jnp.zeros((), bid.dtype)
        return jax.lax.dynamic_update_slice(
            pl, val[None, None].astype(pl.dtype),
            (bid, o.astype(bid.dtype), z, z)), None

    pl, _ = jax.lax.scan(step, pool, (table, blk, off, vals[:, 0]))
    return pl


def paged_pool_view(pool, table):
    """Materialize each lane's owned blocks as a contiguous (B, T, H, D)
    view, T = MBL * block_size, via a sequential dynamic_slice walk over
    the block table (unowned slots read the trash block — garbage, but
    always causally masked because they sit past the lane's position).
    Out-of-range ids (corrupted table entries) also read the trash block
    instead of dynamic_slice's silent clamp-to-last-block, so a corrupt
    entry can never leak another lane's KV into this lane's scores."""
    NB, bs, H, D = pool.shape
    B, MBL = table.shape
    table = jnp.where((table >= 0) & (table < NB), table, TRASH_BLOCK)
    out = jnp.zeros((B, MBL * bs, H, D), pool.dtype)
    lanes = jnp.asarray(np.repeat(np.arange(B, dtype=np.int32), MBL))
    slots = jnp.asarray(np.tile(np.arange(MBL, dtype=np.int32), B))

    def step(o, x):
        lane, j, bid = x
        z = jnp.zeros((), bid.dtype)
        blkv = jax.lax.dynamic_slice(pool, (bid, z, z, z), (1, bs, H, D))
        return jax.lax.dynamic_update_slice(
            o, blkv, (lane.astype(bid.dtype), (j * bs).astype(bid.dtype),
                      z, z)), None

    out, _ = jax.lax.scan(step, out, (lanes, slots, table.reshape(-1)))
    return out


def paged_scatter_rows(pool, rows, scatter_table):
    """Scatter contiguous prefill rows into the block pool.

    rows (Bp, S, H, D) from a fresh contiguous row cache; scatter_table
    (Bp, ceil(S/bs)) int32 block ids — entries past a row's owned blocks
    (and whole padding rows) point at TRASH_BLOCK, which absorbs them.
    """
    NB, bs, H, D = pool.shape
    Bp, S = rows.shape[:2]
    pad = (-S) % bs
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = rows.shape[1] // bs
    blocks = rows.reshape(Bp * nb, bs, H, D).astype(pool.dtype)

    def step(pl, x):
        bid, blkv = x
        z = jnp.zeros((), bid.dtype)
        return jax.lax.dynamic_update_slice(pl, blkv[None], (bid, z, z, z)), None

    pl, _ = jax.lax.scan(step, pool, (scatter_table.reshape(-1), blocks))
    return pl


# --------------------------------------------------------------------------
# attention (GQA, optional sliding window, optional cross)
# --------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig) -> Params:
    d, dt = cfg.d_model, cfg.pdtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.d_head_total, dt),
        "wk": dense_init(ks[1], d, cfg.d_kv_total, dt),
        "wv": dense_init(ks[2], d, cfg.d_kv_total, dt),
        "wo": dense_init(ks[3], cfg.d_head_total, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.d_head_total,), dt)
        p["bk"] = jnp.zeros((cfg.d_kv_total,), dt)
        p["bv"] = jnp.zeros((cfg.d_kv_total,), dt)
    return p


def _split_heads(x, n, dh):
    B, S, _ = x.shape
    return x.reshape(B, S, n, dh)


# Sequence sizes at/above this use the flash (online-softmax) path; below
# it the plain einsum path is cheaper to compile. Both are numerically
# equivalent (tested) so the threshold is purely a compile/memory choice.
FLASH_MIN_ELEMS = 512 * 1024


def _attn_plain(q, k, v, qpos, kpos, *, causal, window, t_sharded=False):
    """q (B,S,H,D), k/v (B,T,H,D) (kv already repeated to q heads so the
    head axis shards cleanly); qpos (B,S), kpos (T,) or (B,T) absolute
    positions (kpos = -1 marks empty cache slots). t_sharded: pin scores
    to length-sharding (decode against a T-sharded cache: the softmax
    becomes the partial-softmax combine, the cache never gathers)."""
    D = q.shape[-1]
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores / (D ** 0.5)
    if t_sharded:
        scores = constrain(scores, dp_axes(), None, None, "model")
    kp = kpos if kpos.ndim == 2 else kpos[None]       # (B|1, T)
    valid = (kp >= 0)[:, None, None, :]
    if causal:
        rel = kp[:, None, :] <= qpos[:, :, None]      # (B, S, T)
        valid = jnp.logical_and(valid, rel[:, None])
        if window is not None:
            wn = kp[:, None, :] > qpos[:, :, None] - window
            valid = jnp.logical_and(valid, wn[:, None])
    scores = jnp.where(valid, scores, jnp.finfo(jnp.float32).min)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", w, v)


def _attn_flash(q, k, v, qpos, kpos, *, causal, window, chunk=1024):
    """Online-softmax attention, scanning key/value chunks: peak memory is
    O(S * chunk) per head instead of O(S * T). Same signature as plain."""
    B, S, H, D = q.shape
    T = k.shape[1]
    chunk = min(chunk, T)
    kp2 = kpos if kpos.ndim == 2 else kpos[None]
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp2 = jnp.pad(kp2, ((0, 0), (0, pad)), constant_values=-1)
    nc = k.shape[1] // chunk
    kc = k.reshape(B, nc, chunk, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, H, D).transpose(1, 0, 2, 3, 4)
    pc = kp2.reshape(kp2.shape[0], nc, chunk).transpose(1, 0, 2)  # (nc,B|1,C)
    qf = q.astype(jnp.float32)
    scale = 1.0 / (D ** 0.5)

    # Pin batch->DP, heads->model through the scan. Without this the
    # replicated carry init poisons GSPMD propagation and the O(S*chunk)
    # score tensors replicate across the data axis (measured 16x traffic
    # blowup on yi-34b train). allow_uneven: 56 heads over 16 shards pads.
    dp = dp_axes()
    qf = constrain(qf, dp, None, "model", None, allow_uneven=True)
    kc = constrain(kc, None, dp, None, "model", None, allow_uneven=True)
    vc = constrain(vc, None, dp, None, "model", None, allow_uneven=True)

    # S x chunk tiles are materialized in the model compute dtype (bf16
    # halves the dominant flash traffic — the flash-attn norm); score
    # accumulation and m/l statistics stay f32. f32 inputs (tests/oracles)
    # keep f32 tiles for exactness vs the plain path.
    tile_dt = jnp.bfloat16 if q.dtype == jnp.bfloat16 else jnp.float32

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp
        s = jax.lax.dot_general(
            qf.astype(tile_dt), kb.astype(tile_dt),
            (((3,), (3,)), ((0, 2), (0, 2))),
            preferred_element_type=jnp.float32)  # (B,H,S,chunk)
        s = s * scale
        s = constrain(s, dp, "model", None, None, allow_uneven=True)
        valid = (pb >= 0)[:, None, None, :]
        if causal:
            rel = pb[:, None, :] <= qpos[:, :, None]
            valid = jnp.logical_and(valid, rel[:, None])
            if window is not None:
                wn = pb[:, None, :] > qpos[:, :, None] - window
                valid = jnp.logical_and(valid, wn[:, None])
        s = jnp.where(valid, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p_ = jnp.exp(s - m_safe[..., None])
        p_ = jnp.where(valid, p_, 0.0).astype(tile_dt)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p_.astype(jnp.float32).sum(axis=-1)
        pv = jax.lax.dot_general(
            p_, vb.astype(tile_dt),
            (((3,), (1,)), ((0, 1), (0, 2))),
            preferred_element_type=jnp.float32)  # (B,H,S,D)
        acc_new = acc * corr[..., None] + pv
        acc_new = constrain(acc_new, dp, "model", None, None,
                            allow_uneven=True)
        return (m_new, l_new, acc_new), None

    m0 = constrain(jnp.full((B, H, S), -jnp.inf, jnp.float32),
                   dp, "model", None, allow_uneven=True)
    l0 = constrain(jnp.zeros((B, H, S), jnp.float32),
                   dp, "model", None, allow_uneven=True)
    a0 = constrain(jnp.zeros((B, H, S, D), jnp.float32),
                   dp, "model", None, None, allow_uneven=True)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(v.dtype)  # (B,S,H,D)


def _attn_core(q, k, v, qpos, kpos, *, causal, window, t_sharded=False):
    """GQA via explicit kv repeat: (B,T,Hkv,D) -> (B,T,Hq,D). A (kv, G)
    grouping reshape is NOT sharding-compatible when Hq doesn't divide the
    model axis (e.g. 56 heads / 16) and forced GSPMD to replicate every
    attention tensor; the repeat keeps the single head axis sharded and
    costs only the (sharded) kv broadcast."""
    B, S, Hq, D = q.shape
    Hkv, T = k.shape[2], k.shape[1]
    if Hkv != Hq:
        k = jnp.repeat(k, Hq // Hkv, axis=2)
        v = jnp.repeat(v, Hq // Hkv, axis=2)
    if S * T >= FLASH_MIN_ELEMS:
        return _attn_flash(q, k, v, qpos, kpos, causal=causal, window=window)
    return _attn_plain(q, k, v, qpos, kpos, causal=causal, window=window,
                       t_sharded=t_sharded)


def attention_apply(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,                 # (B, S, d)
    positions: jax.Array,         # (B, S) absolute positions
    eng: DotEngine,
    *,
    kv_cache: Optional[Dict[str, jax.Array]] = None,  # {"k","v" (B,T,Hkv,D), "len" ()}
    memory: Optional[jax.Array] = None,               # cross-attn memory (B,M,d)
    causal: bool = True,
    chunked: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self- or cross-attention with optional KV cache (decode) and SWA.

    The cache dict selects the layout: {"k","v","len"} is the contiguous
    per-lane layout; {"kpool","vpool","table","len"} is the paged layout
    (see the block-pool helpers above). `chunked=True` treats an S>1 call
    like a decode step that writes S entries at each lane's position and
    attends over the whole cache (chunked prefill); the default S>1 path
    is fresh whole-prompt prefill.

    Returns (output (B,S,d), updated kv_cache or None).
    """
    B, S, d = x.shape
    Dh = cfg.head_dim
    q = eng.dot(x, p["wq"])
    src = memory if memory is not None else x
    k = eng.dot(src, p["wk"])
    v = eng.dot(src, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = _split_heads(q, cfg.n_heads, Dh)
    k = _split_heads(k, cfg.n_kv_heads, Dh)
    v = _split_heads(v, cfg.n_kv_heads, Dh)
    if memory is None:  # RoPE only for self-attention
        q = apply_rope(q, positions, style=cfg.rope_style, theta=cfg.rope_theta)
        k = apply_rope(k, positions, style=cfg.rope_style, theta=cfg.rope_theta)

    window = cfg.sliding_window if memory is None else None
    new_cache = None
    if kv_cache is not None and memory is None and "kpool" in kv_cache:
        # paged decode: write this step through the block table, then
        # attend over the gather-free contiguous view of owned blocks.
        if S != 1:
            raise ValueError(
                "paged KV cache supports decode steps only (S == 1); "
                "prefill goes through a contiguous row cache that the "
                "serving engine scatters into the pool")
        from repro.distributed.constraints import mesh_axes
        msize = mesh_axes().get("model", 1)
        t_sharded = msize > 1 and cfg.n_kv_heads % msize != 0
        table = kv_cache["table"]
        lane_pos = positions[:, 0]
        kpool = paged_pool_write(kv_cache["kpool"], table, lane_pos, k)
        vpool = paged_pool_write(kv_cache["vpool"], table, lane_pos, v)
        new_cache = {"kpool": kpool, "vpool": vpool, "table": table,
                     "len": jnp.maximum(kv_cache["len"], lane_pos.max() + 1)}
        ck = paged_pool_view(kpool, table)
        cv = paged_pool_view(vpool, table)
        # view slot index == absolute position, exactly the contiguous
        # layout; unowned slots hold trash but sit past lane_pos, so the
        # causal mask zeroes them (exp underflows to exact 0.0) and the
        # softmax is bit-identical to the contiguous path.
        kpos = jnp.arange(ck.shape[1])
        out = _attn_core(q, ck, cv, positions, kpos,
                         causal=causal, window=window, t_sharded=t_sharded)
        out = eng.dot(out.reshape(B, S, cfg.d_head_total), p["wo"])
        return out, new_cache
    if kv_cache is not None and memory is None:
        T = kv_cache["k"].shape[1]
        cur = kv_cache["len"]
        ring = window is not None and T == window
        if S == 1 or chunked:
            # decode / chunked prefill: per-lane write of S entries at each
            # lane's own position (lanes in a serving pool are at
            # heterogeneous depths), then attend over the whole cache
            from repro.distributed.constraints import mesh_axes
            msize = mesh_axes().get("model", 1)
            # cache is LENGTH-sharded when kv heads don't divide the model
            # axis; attention must then compute T-sharded (partial-softmax
            # combine) instead of gathering the full cache per layer
            # (measured: 172 GB/step on qwen1.5-110b decode_32k).
            t_sharded = msize > 1 and cfg.n_kv_heads % msize != 0
            lane_pos = positions[:, 0]
            if ring:
                if S != 1:
                    raise ValueError(
                        "chunked prefill does not support sliding-window "
                        "ring caches; disable prefill chunking for SWA "
                        "models")
                idx_b = jnp.mod(lane_pos, T)
            else:
                idx_b = jnp.minimum(lane_pos, T - S)
            # zero indices take i's dtype: mixing traced int32 lane
            # indices with bare Python 0s type-errors under x64
            _upd = lambda c, kk, i: jax.lax.dynamic_update_slice(
                c, kk, (i,) + (jnp.zeros((), i.dtype),) * 2)
            ck = jax.vmap(_upd)(kv_cache["k"],
                                k.astype(kv_cache["k"].dtype), idx_b)
            cv = jax.vmap(_upd)(kv_cache["v"],
                                v.astype(kv_cache["v"].dtype), idx_b)
            new_cache = {"k": ck, "v": cv, "len": jnp.maximum(cur, lane_pos.max() + S)}
            slots = jnp.arange(T)
            if ring:  # per-lane slot->absolute-position map
                newest = lane_pos[:, None]
                kpos = newest - jnp.mod(newest - slots[None], T)
                kpos = jnp.where(kpos >= 0, kpos, -1)
            else:
                kpos = slots  # slot index == absolute position
            out = _attn_core(q, ck, cv, positions, kpos,
                             causal=causal, window=window,
                             t_sharded=t_sharded)
            out = eng.dot(out.reshape(B, S, cfg.d_head_total), p["wo"])
            return out, new_cache
        # prefill: fill the cache so slot s holds position p with
        # s == p mod T (ring) or s == p (full), then attend over the full
        # fresh sequence; the cache is only for later decode steps.
        if S > T:  # SWA prompt longer than the ring: keep last T, aligned
            kw, vw = k[:, -T:], v[:, -T:]
            shift = (S - T) % T
            kw = jnp.roll(kw, shift, axis=1)
            vw = jnp.roll(vw, shift, axis=1)
        else:
            kw, vw = k, v
        # all-Python-int indices: a mixed (0, jnp.int32-zero, 0, 0) tuple
        # type-errors under x64, where bare 0 canonicalizes to int64
        ck = jax.lax.dynamic_update_slice(
            kv_cache["k"], kw.astype(kv_cache["k"].dtype), (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            kv_cache["v"], vw.astype(kv_cache["v"].dtype), (0, 0, 0, 0))
        new_cache = {"k": ck, "v": cv, "len": cur + S}

    if memory is not None:
        kpos = jnp.arange(k.shape[1])
        out = _attn_core(q, k, v, positions, kpos, causal=False, window=None)
    else:
        kpos = jnp.arange(k.shape[1])
        out = _attn_core(q, k, v, positions, kpos, causal=causal,
                         window=window)
    out = eng.dot(out.reshape(B, S, cfg.d_head_total), p["wo"])
    return out, new_cache


def _cache_positions(cur, T, S, window):
    """Absolute position held in each cache slot (-1 = empty), for a cache
    that was just updated with S entries ending at position cur + S - 1."""
    slots = jnp.arange(T)
    if window is not None and T == window:
        newest = cur + S - 1
        pos = newest - jnp.mod(newest - slots, T)
        return jnp.where(pos >= 0, pos, -1)
    return jnp.where(slots < cur + S, slots, -1)


# --------------------------------------------------------------------------
# MLP / MoE-free feed-forward
# --------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, dt = cfg.d_model, cfg.pdtype
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "wg": dense_init(ks[0], d, f, dt),
            "wu": dense_init(ks[1], d, f, dt),
            "wd": dense_init(ks[2], f, d, dt),
        }
    return {
        "wu": dense_init(ks[0], d, f, dt),
        "wd": dense_init(ks[1], f, d, dt),
    }


def mlp_apply(p: Params, cfg: ModelConfig, x: jax.Array, eng: DotEngine) -> jax.Array:
    if cfg.mlp_type == "swiglu":
        g = jax.nn.silu(eng.dot(x, p["wg"]).astype(jnp.float32)).astype(x.dtype)
        u = eng.dot(x, p["wu"])
        return eng.dot(g * u, p["wd"])
    h = jax.nn.gelu(eng.dot(x, p["wu"]).astype(jnp.float32)).astype(x.dtype)
    return eng.dot(h, p["wd"])


# --------------------------------------------------------------------------
# embeddings / head
# --------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig) -> Params:
    e = jax.random.normal(key, (cfg.vocab_padded, cfg.d_model), jnp.float32) * 0.02
    return {"table": e.astype(cfg.pdtype)}


def embed(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return p["table"].astype(cfg.cdtype)[tokens]


def unembed(p: Params, x: jax.Array, cfg: ModelConfig, eng: DotEngine) -> jax.Array:
    logits = eng.dot(x, p["table"].astype(cfg.cdtype).T)
    if cfg.vocab_padded != cfg.vocab_size:
        mask = (jnp.arange(cfg.vocab_padded) >= cfg.vocab_size) * jnp.asarray(
            -1e9, logits.dtype)
        logits = logits + mask
    return logits
