"""Model facade: init / forward / prefill / decode for every family.

The facade hides family differences behind four entry points used by the
training loop, the serving engine and the dry-run:

  init(key)                                  -> params
  forward(params, batch)                     -> logits (B, S, V), aux
  init_cache(batch, max_len)                 -> cache pytree
  prefill(params, batch, cache)              -> (last_logits, cache)
  decode_step(params, token, pos, cache, mem)-> (logits, cache)

`batch` is a dict: tokens (B, S) int32 and, per family, stub frontend
embeddings: "frames" (encdec) or "patches" (vlm) — (B, M, d_model) float.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.numerics import DotEngine
from .config import ModelConfig
from .layers import embed, embedding_init, rmsnorm, rmsnorm_init, unembed
from .transformer import (stack_apply, stack_cache_init, stack_init)

Params = Dict[str, Any]


class Model:
    def __init__(self, cfg: ModelConfig, eng: Optional[DotEngine] = None):
        self.cfg = cfg
        self.eng = eng or DotEngine(mode=cfg.dot_mode)

    # ---------------- init ----------------
    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        params: Params = {
            "embed": embedding_init(ks[0], cfg),
            "blocks": stack_init(ks[1], cfg, cfg.block_pattern,
                                 cfg.pattern_groups, cfg.remainder_blocks),
            "final_norm": rmsnorm_init(cfg.d_model, cfg.pdtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = {
                "table": (jax.random.normal(
                    ks[2], (cfg.vocab_padded, cfg.d_model), jnp.float32)
                    * 0.02).astype(cfg.pdtype)}
        if cfg.n_enc_layers:
            enc_cfg = self._encoder_cfg()
            params["encoder"] = {
                "blocks": stack_init(ks[3], enc_cfg, ("attn",),
                                     cfg.n_enc_layers, ()),
                "final_norm": rmsnorm_init(cfg.d_model, cfg.pdtype),
            }
        return params

    def _encoder_cfg(self) -> ModelConfig:
        import dataclasses
        return dataclasses.replace(
            self.cfg, block_pattern=("attn",), n_layers=self.cfg.n_enc_layers,
            n_experts=0, experts_per_token=0, sliding_window=None,
            mlp_type="gelu")

    # ---------------- memory (frontend) ----------------
    def _memory(self, params: Params, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        if cfg.family == "encdec":
            frames = batch["frames"].astype(cfg.cdtype)  # (B, M, d)
            pos = jnp.broadcast_to(
                jnp.arange(frames.shape[1])[None], frames.shape[:2])
            enc_cfg = self._encoder_cfg()
            h, _, _ = stack_apply(params["encoder"]["blocks"], enc_cfg,
                                  ("attn",), frames, pos, self.eng,
                                  causal=False)
            return rmsnorm(params["encoder"]["final_norm"], h, cfg.norm_eps)
        if cfg.family == "vlm":
            return batch["patches"].astype(cfg.cdtype)
        return None

    # ---------------- full-sequence forward (train / eval) ----------------
    def forward(self, params: Params, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        memory = self._memory(params, batch)
        x = embed(params["embed"], tokens, cfg)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, _, aux = stack_apply(params["blocks"], cfg, cfg.block_pattern,
                                x, pos, self.eng, memory=memory)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        emb = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = unembed(emb, x, cfg, self.eng.for_role("head"))
        return logits.astype(jnp.float32), aux

    # ---------------- KV / recurrent caches ----------------
    def init_cache(self, batch: int, max_len: int,
                   paged: Optional[Dict[str, int]] = None) -> Params:
        """`paged={"num_blocks": NB, "block_size": bs}` gives full-attention
        layers the block-pool KV layout (see transformer.block_cache_init);
        default is the contiguous per-lane layout."""
        cfg = self.cfg
        return stack_cache_init(cfg, cfg.block_pattern, cfg.pattern_groups,
                                cfg.remainder_blocks, batch, max_len,
                                paged=paged)

    @staticmethod
    def _take_last(x: jax.Array, last_index: Optional[jax.Array]) -> jax.Array:
        """x (B, S, d) -> (B, 1, d) at per-lane `last_index` (or S-1)."""
        if last_index is None:
            return x[:, -1:]
        B = x.shape[0]
        idx = jnp.broadcast_to(
            last_index.astype(jnp.int32)[:, None, None], (B, 1, x.shape[-1]))
        return jnp.take_along_axis(x, idx, axis=1)

    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                cache: Params, last_index: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Params, Optional[jax.Array]]:
        """Process the prompt; returns (last-position logits, cache, memory).

        `last_index` (B,) int32 selects each lane's final-prompt position —
        required when prompts are right-padded to a shared bucket length
        (the padded tail writes cache entries past the real prompt, which
        later decode steps overwrite position-for-position, so padding
        never changes attention outputs for causal layers).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        memory = self._memory(params, batch)
        x = embed(params["embed"], tokens, cfg)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, cache, _ = stack_apply(params["blocks"], cfg, cfg.block_pattern,
                                  x, pos, self.eng, caches=cache,
                                  memory=memory)
        x = rmsnorm(params["final_norm"], self._take_last(x, last_index),
                    cfg.norm_eps)
        emb = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = unembed(emb, x, cfg, self.eng.for_role("head"))
        return logits[:, 0].astype(jnp.float32), cache, memory

    def prefill_chunk(self, params: Params, batch: Dict[str, jax.Array],
                      cache: Params, start: jax.Array,
                      last_index: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, Params]:
        """Chunked prefill: run S prompt tokens starting at absolute
        position `start` (scalar int32), attending over the cache's whole
        view so earlier chunks stay visible. Supports full-attention
        patterns only (the serving engine guards); sliding-window rings
        are rejected in layers.attention_apply. Returns (logits at
        `last_index` within the chunk, cache)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        start = jnp.asarray(start, jnp.int32)
        pos = jnp.broadcast_to(start + jnp.arange(S, dtype=jnp.int32)[None],
                               (B, S))
        x = embed(params["embed"], tokens, cfg)
        x, cache, _ = stack_apply(params["blocks"], cfg, cfg.block_pattern,
                                  x, pos, self.eng, caches=cache,
                                  chunked=True)
        x = rmsnorm(params["final_norm"], self._take_last(x, last_index),
                    cfg.norm_eps)
        emb = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = unembed(emb, x, cfg, self.eng.for_role("head"))
        return logits[:, 0].astype(jnp.float32), cache

    def decode_step(self, params: Params, token: jax.Array, pos: jax.Array,
                    cache: Params, memory: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Params]:
        """token (B,) int32, pos (B,) absolute position of `token`."""
        cfg = self.cfg
        x = embed(params["embed"], token[:, None], cfg)
        x, cache, _ = stack_apply(params["blocks"], cfg, cfg.block_pattern,
                                  x, pos[:, None], self.eng, caches=cache,
                                  memory=memory)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        emb = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = unembed(emb, x, cfg, self.eng.for_role("head"))
        return logits[:, 0].astype(jnp.float32), cache


def lm_loss(model: Model, params: Params, batch: Dict[str, jax.Array],
            *, aux_weight: float = 0.01) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Causal LM loss: predict tokens[t+1] from tokens[<=t]."""
    logits, aux = model.forward(params, batch)
    targets = batch["tokens"][:, 1:]
    logits = logits[:, :-1]
    mask = batch.get("mask")
    mask = mask[:, 1:].astype(jnp.float32) if mask is not None else \
        jnp.ones_like(targets, jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux,
                   "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}
