"""Block dispatch + pattern-scanned stacks for every assigned family.

A model is a sequence of blocks tiled from cfg.block_pattern:
  attn  — pre-norm self-attention (GQA/SWA/RoPE) + MLP or MoE
  rec   — pre-norm RG-LRU recurrent mixer + MLP            (recurrentgemma)
  ssm   — Mamba2 SSD block (no separate MLP)               (mamba2)
  cross — pre-norm cross-attention to frontend memory + MLP (llama-vision)
  xdec  — self-attn + cross-attn + MLP                      (seamless decoder)

Whole pattern groups are scanned (jax.lax.scan over stacked params) so
compile time and HLO size are O(len(pattern)) instead of O(n_layers);
remainder layers are materialized individually. Activation checkpointing
wraps the group body (cfg.remat).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.numerics import DotEngine
from .config import ModelConfig
from .layers import (attention_apply, attention_init, mlp_apply, mlp_init,
                     rmsnorm, rmsnorm_init)
from .moe import moe_apply, moe_init
from .recurrent import (rglru_apply, rglru_init, rglru_state_init, ssd_apply,
                        ssd_init, ssd_state_init)

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# single block
# --------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Params = {"norm1": rmsnorm_init(d, cfg.pdtype)}
    if kind == "attn":
        p["attn"] = attention_init(ks[0], cfg)
    elif kind == "rec":
        p["rec"] = rglru_init(ks[0], cfg)
    elif kind == "ssm":
        p["ssm"] = ssd_init(ks[0], cfg)
        return p  # SSD block has no separate MLP
    elif kind == "cross":
        p["cross"] = attention_init(ks[0], cfg)
    elif kind == "xdec":
        p["attn"] = attention_init(ks[0], cfg)
        p["norm_x"] = rmsnorm_init(d, cfg.pdtype)
        p["cross"] = attention_init(ks[1], cfg)
    else:
        raise ValueError(f"unknown block kind {kind}")
    p["norm2"] = rmsnorm_init(d, cfg.pdtype)
    if cfg.n_experts and kind == "attn":
        p["moe"] = moe_init(ks[2], cfg)
    else:
        p["mlp"] = mlp_init(ks[2], cfg)
    return p


def block_cache_init(cfg: ModelConfig, kind: str, batch: int,
                     max_len: int,
                     paged: Optional[Dict[str, int]] = None
                     ) -> Optional[Params]:
    """Per-block decode cache. `paged={"num_blocks": NB, "block_size": bs}`
    switches full-attention KV caches to the block-pool layout (pool +
    per-lane block table; block 0 is the shared trash block, see
    layers.paged_pool_write). Sliding-window layers keep the contiguous
    ring — their residency is already bounded by the window — as do
    recurrent/SSM states (O(1) per lane)."""
    if kind in ("attn", "xdec"):
        T = max_len
        if cfg.sliding_window is not None:
            T = min(T, cfg.sliding_window)
        if paged is not None and cfg.sliding_window is None:
            nb, bs = paged["num_blocks"], paged["block_size"]
            mbl = -(-max_len // bs)
            return {
                "kpool": jnp.zeros((nb, bs, cfg.n_kv_heads, cfg.head_dim),
                                   cfg.cdtype),
                "vpool": jnp.zeros((nb, bs, cfg.n_kv_heads, cfg.head_dim),
                                   cfg.cdtype),
                "table": jnp.zeros((batch, mbl), jnp.int32),
                "len": jnp.zeros((), jnp.int32),
            }
        return {
            "k": jnp.zeros((batch, T, cfg.n_kv_heads, cfg.head_dim), cfg.cdtype),
            "v": jnp.zeros((batch, T, cfg.n_kv_heads, cfg.head_dim), cfg.cdtype),
            "len": jnp.zeros((), jnp.int32),
        }
    if kind == "rec":
        return rglru_state_init(cfg, batch)
    if kind == "ssm":
        return ssd_state_init(cfg, batch)
    if kind == "cross":
        return None
    raise ValueError(kind)


def block_apply(
    p: Params,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    eng: DotEngine,
    *,
    cache: Optional[Params] = None,
    memory: Optional[jax.Array] = None,
    causal: bool = True,
    chunked: bool = False,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (x, new_cache, aux_loss).

    Per-layer precision assignment resolves here: attention-family GEMMs
    run under eng.for_role("attn") and the MLP/MoE under
    eng.for_role("mlp"), so a DotEngine with layer_modes (e.g. MLPs on a
    truncated olm{n}t{p} tier, attention at full width) splits precision
    per role with no other plumbing. Recurrent/SSM mixers keep the base
    engine — their GEMMs are gate projections, not attention."""
    aux = jnp.zeros((), jnp.float32)
    attn_eng = eng.for_role("attn")
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    new_cache = cache
    if kind == "attn":
        o, new_cache = attention_apply(p["attn"], cfg, h, positions,
                                       attn_eng, kv_cache=cache,
                                       causal=causal, chunked=chunked)
    elif kind == "rec":
        o, new_cache = rglru_apply(p["rec"], cfg, h, eng, state=cache)
    elif kind == "ssm":
        o, new_cache = ssd_apply(p["ssm"], cfg, h, eng, state=cache)
        return x + o, new_cache, aux
    elif kind == "cross":
        o, _ = attention_apply(p["cross"], cfg, h, positions, attn_eng,
                               memory=memory)
    elif kind == "xdec":
        o, new_cache = attention_apply(p["attn"], cfg, h, positions,
                                       attn_eng, kv_cache=cache,
                                       causal=causal, chunked=chunked)
        x = x + o
        hx = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        o, _ = attention_apply(p["cross"], cfg, hx, positions, attn_eng,
                               memory=memory)
    else:
        raise ValueError(kind)
    x = x + o
    h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
    mlp_eng = eng.for_role("mlp")
    if "moe" in p:
        m, aux = moe_apply(p["moe"], cfg, h2, mlp_eng)
    else:
        m = mlp_apply(p["mlp"], cfg, h2, mlp_eng)
    return x + m, new_cache, aux


# --------------------------------------------------------------------------
# pattern-scanned stack
# --------------------------------------------------------------------------

def stack_init(key, cfg: ModelConfig, pattern: Tuple[str, ...],
               n_groups: int, remainder: Tuple[str, ...]) -> Params:
    """Params: {"scan": tuple_per_slot(stacked over groups), "rem": [...]}"""
    keys = jax.random.split(key, n_groups * len(pattern) + len(remainder))
    scan_params = []
    for s, kind in enumerate(pattern):
        per_group = [block_init(keys[g * len(pattern) + s], cfg, kind)
                     for g in range(n_groups)]
        scan_params.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
                           if n_groups > 1 else
                           jax.tree.map(lambda v: v[None], per_group[0]))
    rem_params = [block_init(keys[n_groups * len(pattern) + i], cfg, kind)
                  for i, kind in enumerate(remainder)]
    return {"scan": tuple(scan_params), "rem": rem_params}


def stack_cache_init(cfg: ModelConfig, pattern, n_groups, remainder,
                     batch: int, max_len: int,
                     paged: Optional[Dict[str, int]] = None) -> Params:
    scan_caches = []
    for kind in pattern:
        c = block_cache_init(cfg, kind, batch, max_len, paged=paged)
        scan_caches.append(
            jax.tree.map(lambda v: jnp.broadcast_to(v[None], (n_groups,) + v.shape), c)
            if c is not None else None)
    rem = [block_cache_init(cfg, kind, batch, max_len, paged=paged)
           for kind in remainder]
    return {"scan": tuple(scan_caches), "rem": rem}


def stack_apply(
    params: Params,
    cfg: ModelConfig,
    pattern: Tuple[str, ...],
    x: jax.Array,
    positions: jax.Array,
    eng: DotEngine,
    *,
    caches: Optional[Params] = None,
    memory: Optional[jax.Array] = None,
    causal: bool = True,
    chunked: bool = False,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Run the scanned groups then the remainder blocks."""

    def group_body(carry, slice_in):
        xg, aux_acc = carry
        gp, gc = slice_in
        new_caches = []
        for s, kind in enumerate(pattern):
            xg, nc, aux = block_apply(
                gp[s], cfg, kind, xg, positions, eng,
                cache=None if gc is None else gc[s],
                memory=memory, causal=causal, chunked=chunked)
            new_caches.append(nc)
        return (xg, aux_acc + aux), tuple(new_caches)

    # Remat only on the training path: under serving (caches present)
    # there is no backward pass, and the checkpoint barrier blocks GSPMD
    # propagation through the cache update (measured: a full-length f32
    # KV regather per layer on decode_32k).
    if cfg.remat == "block" and caches is None:
        group_body = jax.checkpoint(group_body)

    scan_caches = caches["scan"] if caches is not None else None
    if scan_caches is None:
        scan_caches_in = tuple(None for _ in pattern)
        (x, aux), _ = jax.lax.scan(
            lambda c, gp: group_body((c[0], c[1]), (gp, scan_caches_in)),
            (x, jnp.zeros((), jnp.float32)), params["scan"])
        new_scan_caches = None
    else:
        (x, aux), new_scan_caches = jax.lax.scan(
            lambda c, inp: group_body(c, inp),
            (x, jnp.zeros((), jnp.float32)),
            (params["scan"], scan_caches))

    new_rem = []
    rem_kinds = cfg.remainder_blocks
    for i, kind in enumerate(rem_kinds):
        c = None if caches is None else caches["rem"][i]
        x, nc, a = block_apply(params["rem"][i], cfg, kind, x, positions,
                               eng, cache=c, memory=memory, causal=causal,
                               chunked=chunked)
        new_rem.append(nc)
        aux = aux + a
    new_caches = None
    if caches is not None:
        new_caches = {"scan": new_scan_caches, "rem": new_rem}
    return x, new_caches, aux
