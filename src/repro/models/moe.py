"""Mixture-of-Experts layer: top-k routing, capacity-based sort dispatch.

Dispatch is gather-based (no dense one-hot einsum over experts): token
assignments are sorted by expert id, positions within each expert computed
from the sorted order, and tokens gathered into an (E, C, d) buffer.
Tokens beyond an expert's capacity are dropped (standard capacity-factor
semantics); their combine weight is zero so the residual passes through.

Sharding (distributed/sharding.py):
  * moe_sharding="ep": expert axis E sharded over the model axis
    (E % model == 0, e.g. qwen3 128/16); XLA inserts the all-to-all at the
    data->expert boundary from the sharding constraints.
  * moe_sharding="tp": d_ff sharded over the model axis within every
    expert (mixtral: 8 experts < 16-way model axis).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.numerics import DotEngine
from repro.distributed.constraints import constrain, dp_axes
from .config import ModelConfig
from .layers import dense_init

Params = Dict[str, Any]


def moe_init(key, cfg: ModelConfig) -> Params:
    d, f, E, dt = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.pdtype
    ks = jax.random.split(key, 4)
    def stack(k, din, dout):
        kk = jax.random.split(k, E)
        return jnp.stack([dense_init(kk[e], din, dout, dt) for e in range(E)])
    return {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "wg": stack(ks[1], d, f),   # (E, d, f)
        "wu": stack(ks[2], d, f),
        "wd": stack(ks[3], f, d),
    }


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def _route_row(xt, router, cfg: ModelConfig):
    """Route one batch row's T tokens. xt (T, d). Returns dispatch plan.

    Per-row routing keeps the argsort local to the row, so under data
    parallelism the dispatch needs no cross-shard resorting; only the
    expert FFN einsum crosses the data/model (EP) boundary (all-to-all
    inserted by GSPMD from the sharding constraints).
    """
    T, d = xt.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    C = _capacity(T, cfg)
    gates = jax.nn.softmax(
        jnp.einsum("td,de->te", xt.astype(jnp.float32), router), axis=-1)
    topw, topi = jax.lax.top_k(gates, K)               # (T, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    me = gates.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    flat_e = topi.reshape(-1)                          # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    pos = jnp.arange(T * K) - jnp.searchsorted(se, se, side="left")
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)        # overflow -> sink
    buf_tok = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        st.astype(jnp.int32))
    return buf_tok[:-1], slot, st, sw, keep, aux


def moe_apply(p: Params, cfg: ModelConfig, x: jax.Array, eng: DotEngine) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (output (B, S, d), aux_loss ()). Routing is per
    batch row (vmapped); experts run one einsum over (B, E, C, d)."""
    B, S, d = x.shape
    E = cfg.n_experts
    C = _capacity(S, cfg)  # per-row capacity (static)

    buf_tok, slot, st, sw, keep, aux = jax.vmap(
        lambda row: _route_row(row, p["router"], cfg))(x)
    aux = aux.mean()

    dp = dp_axes()
    ep = "model" if cfg.moe_sharding == "ep" else None
    ffn_tp = None if cfg.moe_sharding == "ep" else "model"

    # Dispatch/combine keep indices shaped (E, C): any reshape that merges
    # or splits the sharded expert axis (e.g. (B, E*C, d)) forces GSPMD to
    # all-gather the full dispatch buffer (measured 2 TB/step on qwen3);
    # with (E, C)-shaped gathers/scatter-adds the op partitions over E and
    # the combine reduces with one (B, S, d) all-reduce.
    buf_ec = buf_tok.reshape(B, E, C)                  # token id per slot
    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    x_pad = constrain(x_pad, dp, None, None)
    xe = jax.vmap(lambda xp, idx: xp[idx])(x_pad, buf_ec)  # (B, E, C, d)
    xe = constrain(xe, dp, ep, None, None)

    wg = p["wg"].astype(x.dtype)
    wu = p["wu"].astype(x.dtype)
    wd = p["wd"].astype(x.dtype)
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, wg)
                    .astype(jnp.float32)).astype(x.dtype)
    u = jnp.einsum("becd,edf->becf", xe, wu)
    g = constrain(g, dp, ep, None, ffn_tp)
    u = constrain(u, dp, ep, None, ffn_tp)
    ye = jnp.einsum("becf,efd->becd", g * u, wd)       # (B, E, C, d)
    ye = constrain(ye, dp, ep, None, None)

    # per-slot combine weights aligned to the (E, C) buffer
    wslot = jax.vmap(
        lambda sl, w: jnp.zeros((E * C + 1,), jnp.float32)
        .at[sl].set(w)[:-1])(slot, jnp.where(keep, sw, 0.0))
    wec = wslot.reshape(B, E, C)
    upd = ye * wec[..., None].astype(x.dtype)          # (B, E, C, d)
    upd = constrain(upd, dp, ep, None, None)

    def combine(buf_row, upd_row):                     # (E,C), (E,C,d)
        o = jnp.zeros((S + 1, d), x.dtype)
        return o.at[jnp.minimum(buf_row, S)].add(upd_row)[:S]
    out = jax.vmap(combine)(buf_ec, upd)
    out = constrain(out, dp, None, None)
    return out, aux
