"""Unified architecture configuration for all assigned model families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config type drives every assigned architecture.

    family: dense | moe | hybrid | ssm | encdec | vlm
    block_pattern: per-layer block kinds, tiled across n_layers; a scan
      runs over whole pattern groups, remainder layers are materialized
      individually (e.g. recurrentgemma 38 = 12*(rec,rec,attn) + 2 rec).
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None        # default: d_model // n_heads
    # --- attention ---
    qkv_bias: bool = False
    rope_style: str = "full"              # full | half (chatglm 2d-RoPE)
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # SWA / local attention window
    # --- mlp ---
    mlp_type: str = "swiglu"              # swiglu | gelu
    # --- moe ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # --- hybrid (RG-LRU) ---
    block_pattern: Tuple[str, ...] = ("attn",)
    rnn_width: Optional[int] = None       # RG-LRU recurrence width
    conv_width: int = 4
    # --- ssm (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    # --- enc-dec / vlm frontends (stubs provide embeddings) ---
    n_enc_layers: int = 0
    n_frontend_tokens: int = 0            # audio frames / image patches
    # --- numerics / misc ---
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    dot_mode: str = "native"              # any registered DotEngine mode:
                                          # native | tpmm{8,16} | olm{8,16}
    tie_embeddings: bool = False
    # --- distribution hints (see distributed/sharding.py) ---
    sharding_profile: str = "tp"          # tp | fsdp_tp
    moe_sharding: str = "ep"              # ep (experts) | tp (d_ff)
    remat: str = "block"                  # none | block | full

    def __post_init__(self):
        if self.head_dim is None and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family in ("moe",) and not self.n_experts:
            raise ValueError("moe family needs n_experts")
        if len(self.block_pattern) == 0:
            raise ValueError("block_pattern must be nonempty")
        from repro.core.numerics import DotEngine
        if self.dot_mode not in DotEngine.modes():
            raise ValueError(
                f"dot_mode {self.dot_mode!r} is not a registered DotEngine "
                f"mode; choose from {DotEngine.modes()}")

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to 256 so the embedding shards evenly over any
        mesh axis (standard practice); padded logits are masked to -1e9
        (layers.unembed), data generation stays within vocab_size."""
        return -(-self.vocab_size // 256) * 256

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def d_kv_total(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def pattern_groups(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def remainder_blocks(self) -> Tuple[str, ...]:
        rem = self.n_layers % len(self.block_pattern)
        return self.block_pattern[:rem]

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, h = self.d_model, self.head_dim
        counts = 0
        kinds = list(self.block_pattern) * self.pattern_groups + list(self.remainder_blocks)
        for kind in kinds:
            if kind in ("attn", "cross"):
                counts += d * (self.n_heads * h) + d * (2 * self.n_kv_heads * h)
                counts += (self.n_heads * h) * d
                if self.qkv_bias:
                    counts += self.n_heads * h + 2 * self.n_kv_heads * h
            if kind in ("attn", "cross", "rec"):
                if self.n_experts:
                    counts += self.n_experts * 3 * d * self.d_ff + d * self.n_experts
                elif self.mlp_type == "swiglu":
                    counts += 3 * d * self.d_ff
                else:
                    counts += 2 * d * self.d_ff
            if kind == "rec":
                w = self.rnn_width or d
                counts += 2 * d * w + w * d + w * self.conv_width + 2 * w
                # replace the attn qkv counted above? rec blocks counted via
                # the branch below only; attn parts not added for rec.
            if kind == "ssm":
                din, N, H = self.d_inner, self.ssm_state, self.ssm_nheads
                counts += d * (2 * din + 2 * N + H) + din * d
                counts += (din + 2 * N) * self.conv_width + 2 * H
            counts += 2 * d  # norms
        counts += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.n_enc_layers:
            enc = self.n_enc_layers * (4 * d * d + (2 if self.mlp_type == "gelu" else 3) * d * self.d_ff + 2 * d)
            counts += enc
        return counts
