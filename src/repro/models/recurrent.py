"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin) and Mamba2 SSD.

Both are attention-free sequence mixers with O(1) decode state, which is
what makes the long_500k decode shape feasible for these families.

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = a^(c * r_t)      (a = sigmoid(Lambda), c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
computed with an associative scan over (a, b) pairs in train/prefill and a
single fused step in decode.

Mamba2 SSD (arXiv:2405.21060) chunked algorithm: intra-chunk quadratic
term + inter-chunk recurrent state passing (matmul-dominated, which is why
the paper's truncated-precision inner products still apply here).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.numerics import DotEngine
from .config import ModelConfig
from .layers import dense_init

Params = Dict[str, Any]

RGLRU_C = 8.0


# --------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block: conv1d + gated linear recurrence)
# --------------------------------------------------------------------------

def rglru_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    w = cfg.rnn_width or d
    ks = jax.random.split(key, 7)
    # Lambda init so a = sigmoid(L)^c is in ~(0.9, 0.999)
    lam = jax.random.uniform(ks[0], (w,), jnp.float32, 2.0, 6.0)
    return {
        "wx": dense_init(ks[1], d, w, cfg.pdtype),     # recurrence branch
        "wy": dense_init(ks[2], d, w, cfg.pdtype),     # gate branch
        "conv": (jax.random.normal(ks[3], (cfg.conv_width, w), jnp.float32)
                 * 0.1).astype(cfg.pdtype),
        "wa": dense_init(ks[4], w, w, cfg.pdtype),
        "ba": jnp.zeros((w,), cfg.pdtype),
        "wi": dense_init(ks[5], w, w, cfg.pdtype),
        "bi": jnp.zeros((w,), cfg.pdtype),
        "lam": lam,
        "wo": dense_init(ks[6], w, d, cfg.pdtype),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array,
                 state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x (B,S,w), kernel (K,w). Returns (y, new state
    (B,K-1,w)) so decode carries the last K-1 inputs."""
    K = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * kernel[i].astype(x.dtype)[None, None]
            for i in range(K))
    return y, xp[:, -(K - 1):, :]


def _rglru_coeffs(p, u, x_dtype):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, p["wa"].astype(u.dtype))
                       .astype(jnp.float32) + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, p["wi"].astype(u.dtype))
                       .astype(jnp.float32) + p["bi"].astype(jnp.float32))
    log_a = RGLRU_C * r * jax.nn.log_sigmoid(p["lam"])[None, None]
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, b


def rglru_apply(p: Params, cfg: ModelConfig, x: jax.Array, eng: DotEngine,
                state: Optional[Dict[str, jax.Array]] = None
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x (B,S,d). state = {"h": (B,w), "conv": (B,K-1,w)} for decode."""
    B, S, _ = x.shape
    u = eng.dot(x, p["wx"])                           # (B,S,w)
    gate = jax.nn.gelu(eng.dot(x, p["wy"]).astype(jnp.float32))
    conv_state = state["conv"] if state is not None else None
    u, new_conv = _causal_conv(u, p["conv"], conv_state)
    a, b = _rglru_coeffs(p, u, x.dtype)

    if state is not None and S == 1:
        h = a[:, 0] * state["h"] + b[:, 0]            # single decode step
        new_state = {"h": h, "conv": new_conv}
        h = h[:, None]
    else:
        # parallel associative scan: h_t = a_t h_{t-1} + b_t, from h0
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        a_run, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        if state is not None:                          # prefill from state
            h = h + a_run * state["h"][:, None]
        new_state = None if state is None else \
            {"h": h[:, -1], "conv": new_conv}
    y = (h.astype(x.dtype) * gate.astype(x.dtype))
    return eng.dot(y, p["wo"]), new_state


def rglru_state_init(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    w = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32),
    }


# --------------------------------------------------------------------------
# Mamba2 / SSD block
# --------------------------------------------------------------------------

def ssd_init(key, cfg: ModelConfig) -> Params:
    d, din, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    ks = jax.random.split(key, 5)
    return {
        "win": dense_init(ks[0], d, 2 * din + 2 * N + H, cfg.pdtype),
        "conv": (jax.random.normal(ks[1], (cfg.conv_width, din + 2 * N), jnp.float32)
                 * 0.1).astype(cfg.pdtype),
        "a_log": jnp.log(jax.random.uniform(ks[2], (H,), jnp.float32, 1.0, 16.0)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((din,), cfg.pdtype),
        "wout": dense_init(ks[3], din, d, cfg.pdtype),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x (..., L) -> (..., L, L) lower-triangular segment sums."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, h0=None):
    """SSD forward: xh (B,S,H,P), dt (B,S,H) >=0, A (H,) <0 decay rates,
    Bm/Cm (B,S,N), optional initial state h0 (B,H,P,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    xc = xh.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)
    dA = dtc * A[None, None, None]                    # (B,nc,L,H) (negative)
    dA = jnp.moveaxis(dA, -1, 2)                      # (B,nc,H,L)

    # intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(dA))                       # (B,nc,H,L,L)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)    # (B,nc,L,S=L)
    y_diag = jnp.einsum("bchls,bcls,bcsh,bcshp->bclhp",
                        Lmat, scores, dtc, xc)

    # chunk-final states
    decay_to_end = jnp.exp(jnp.cumsum(dA[..., ::-1], axis=-1)[..., ::-1]
                           - dA)                      # (B,nc,H,L): prod_{>l}
    states = jnp.einsum("bchl,bclh,bcln,bclhp->bchpn",
                        decay_to_end, dtc, Bc, xc)    # (B,nc,H,P,N)

    # inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(jnp.sum(dA, axis=-1))       # (B,nc,H)

    def scan_fn(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None] + st
        return h_new, h
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    hT, h_prev = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)               # state entering chunk

    # contribution of previous state to each position
    decay_in = jnp.exp(jnp.cumsum(dA, axis=-1))       # (B,nc,H,L)
    y_off = jnp.einsum("bcln,bchl,bchpn->bclhp",
                       Cc, decay_in, h_prev.astype(Cc.dtype))
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, hT


def ssd_apply(p: Params, cfg: ModelConfig, x: jax.Array, eng: DotEngine,
              state: Optional[Dict[str, jax.Array]] = None
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x (B,S,d). state = {"h": (B,H,P,N), "conv": (B,K-1,din+2N)}."""
    B, S, d = x.shape
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    zxbcdt = eng.dot(x, p["win"])
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv"], conv_state)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xin, Bm, Cm = jnp.split(conv_out, [din, din + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["a_log"])                          # (H,) negative rates
    xh = xin.reshape(B, S, H, P)

    if state is not None and S == 1:
        # single-token recurrent update
        dA = jnp.exp(dt[:, 0] * A[None])              # (B,H)
        h = state["h"] * dA[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, 0], Bm[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)[:, None]
        new_state = {"h": h, "conv": new_conv}
    else:
        # pad to a chunk multiple; padded steps get dt = 0 (identity decay,
        # zero input) so the carried-out state is exact
        pad = (-S) % cfg.ssm_chunk
        xh_p = jnp.pad(xh.astype(jnp.float32), ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bp = jnp.pad(Bm.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
        Cp = jnp.pad(Cm.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
        h0 = state["h"] if state is not None else None
        y, hT = ssd_chunked(xh_p, dt_p, A, Bp, Cp, cfg.ssm_chunk, h0=h0)
        y = y[:, :S]
        new_state = None if state is None else {"h": hT, "conv": new_conv}
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, din)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    # grouped RMS norm
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm"].astype(jnp.float32))
    out = eng.dot(y.astype(x.dtype), p["wout"])
    return out, new_state


def ssd_state_init(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    return {
        "h": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state),
                       jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), jnp.float32),
    }
