"""Fault tolerance: preemption handling, straggler watchdog, restart logic.

Designed for the 1000+ node regime where *something* is always failing:

  * PreemptionGuard — SIGTERM/SIGINT flips a flag; the train loop saves a
    final checkpoint and exits cleanly (checkpoint/restart recovery).
  * StragglerWatchdog — per-step wall-time EMA + z-score; flags outlier
    steps. On real clusters a flagged host triggers the configured policy
    (log | exclude-and-rescale | abort-for-reschedule). Exclusion uses the
    elastic restore path: reshape the mesh without the sick host and
    restore the latest checkpoint onto it.
  * retry_step — retries transient step failures (preempted collectives
    surface as RuntimeError) with exponential backoff before escalating.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, List, Optional

__all__ = ["PreemptionGuard", "StragglerWatchdog", "retry_step"]


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.preempted = False
        self._old = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._old.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self.preempted = True


class StragglerWatchdog:
    """Step-time EMA + z-score straggler detector."""

    def __init__(self, *, alpha: float = 0.05, z_threshold: float = 4.0,
                 warmup_steps: int = 10,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.alpha = alpha
        self.z = z_threshold
        self.warmup = warmup_steps
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.flagged: List[int] = []
        self.on_straggler = on_straggler
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> bool:
        assert self._t0 is not None, "start() not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            # seed statistics
            d = dt - self.mean
            self.mean += d / self.n
            self.var += d * (dt - self.mean)
            return False
        std = max((self.var / max(self.n - 1, 1)) ** 0.5, 1e-9)
        is_straggler = (dt - self.mean) / std > self.z
        if is_straggler:
            self.flagged.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt)
        # EMA update (outliers damped so one straggler doesn't poison stats)
        w = self.alpha * (0.1 if is_straggler else 1.0)
        self.mean = (1 - w) * self.mean + w * dt
        return is_straggler


def retry_step(fn, *args, retries: int = 2, backoff: float = 1.0):
    """Run fn(*args); on transient RuntimeError retry with backoff."""
    last = None
    for attempt in range(retries + 1):
        try:
            return fn(*args)
        except RuntimeError as e:  # collectives on preempted peers
            last = e
            if attempt == retries:
                raise
            time.sleep(backoff * (2 ** attempt))
    raise last  # pragma: no cover
