"""Distributed train / prefill / decode step builders (pjit).

build_train_step: loss + grad + AdamW update, with
  * microbatched gradient accumulation (lax.scan) — XLA overlaps the
    microbatch-k gradient reduce-scatter with microbatch-(k+1) compute,
    the software-pipelining analogue of the paper's overlapped online
    operators;
  * optional int8 error-feedback gradient compression before the DP
    reduction (cross-pod DCN traffic);
  * sharding constraints on the residual stream (optional sequence
    sharding, cuts activation memory by the model-axis size).

All builders return (jitted_fn, in_shardings, out_shardings) so the
dry-run can .lower()/.compile() against ShapeDtypeStructs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shardings_for
from repro.core.numerics import EngineSpec, resolve_engine
from repro.models.config import ModelConfig
from repro.models.model import Model, lm_loss
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import ef_compress_tree
from repro.optim.schedule import cosine_schedule
from .sharding import Sharder

__all__ = ["TrainState", "build_train_step", "build_prefill_step",
           "build_decode_step", "init_train_state"]


def init_train_state(model: Model, key) -> Dict[str, Any]:
    params = model.init(key)
    return {
        "params": params,
        "opt": adamw_init(params),
        "ef": None,  # error-feedback state, created on first compressed step
    }


def train_state_specs(sharder: Sharder, state) -> Any:
    pspecs = sharder.param_specs(state["params"])
    return {
        "params": pspecs,
        "opt": {
            "m": pspecs,
            "v": pspecs,
            "step": P(),
        },
        "ef": None if state["ef"] is None else pspecs,
    }


def build_train_step(
    model: Model,
    sharder: Sharder,
    *,
    opt_cfg: Optional[AdamWConfig] = None,
    microbatches: int = 1,
    compress_grads: bool = False,
    schedule_total: int = 10_000,
    engine_spec: Optional[EngineSpec] = None,
):
    """Returns (train_step(state, batch) -> (state, metrics), specs).

    engine_spec: optional numerics override for this training run — an
    EngineSpec resolved against the model's engine on the sharder's
    mesh (core.numerics.resolve_engine), so the dot_mode / trunc /
    tiling knobs AND the mesh-sharded dispatch (spec.shard="m"/"n"/"k")
    ride one declarative object. With spec.shard set, every weight GEMM
    in the step runs through the shard_map olm front-end on this mesh.
    """
    if engine_spec is not None:
        model = Model(model.cfg, resolve_engine(
            engine_spec, base=model.eng, mesh=sharder.mesh))
    cfg = model.cfg
    opt_cfg = opt_cfg or AdamWConfig()
    act_spec = sharder.activation_spec()

    def _cast_params(params):
        """Mixed precision with the cast pinned BEFORE the FSDP gathers:
        convert f32 master weights to the compute dtype while still
        sharded (with_sharding_constraint to the param spec), so GSPMD
        all-gathers bf16 instead of f32 — halves ZeRO-3 gather bytes.
        Grads flow back through the convert and arrive f32."""
        leaves, td = jax.tree_util.tree_flatten(params)
        specs = td.flatten_up_to(sharder.param_specs(params))
        out = []
        for p, spec in zip(leaves, specs):
            if p.ndim >= 2 and p.dtype == jnp.float32:
                p = jax.lax.with_sharding_constraint(
                    p.astype(cfg.cdtype), spec)
            out.append(p)
        return td.unflatten(out)

    def loss_fn(params, batch):
        return lm_loss(model, _cast_params(params), batch)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state, batch):
        params = state["params"]
        if microbatches > 1:
            def mb(batch_slice):
                return grads_of(params, batch_slice)

            bspec = sharder.batch_spec()

            def split(x):
                # (B, ...) -> (mb, B/mb, ...) keeping the ORIGINAL batch
                # sharding on the B/mb axis: reshape to (B/mb, mb) first so
                # each microbatch takes a strided slice of rows — a direct
                # (mb, B/mb) reshape interleaves shard blocks across both
                # factors and GSPMD silently replicates the batch (observed:
                # multi-pod gave zero speedup on the dense-FSDP archs).
                B = x.shape[0]
                y = x.reshape(B // microbatches, microbatches, *x.shape[1:])
                y = jnp.swapaxes(y, 0, 1)
                spec = P(None, bspec[0], *([None] * (y.ndim - 2)))
                return jax.lax.with_sharding_constraint(y, spec)

            mb_batches = {k: split(v) for k, v in batch.items()}

            def scan_body(carry, mb_batch):
                acc, loss_acc = carry
                mb_batch = {
                    k: jax.lax.with_sharding_constraint(
                        v, P(bspec[0], *([None] * (v.ndim - 1))))
                    for k, v in mb_batch.items()}
                loss, metrics, grads = mb(mb_batch)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), metrics

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), metrics = jax.lax.scan(
                scan_body, (zero, jnp.zeros((), jnp.float32)), mb_batches)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            loss, metrics, grads = grads_of(params, batch)

        ef = state["ef"]
        if compress_grads:
            grads, ef = ef_compress_tree(grads, ef)

        lr_scale = cosine_schedule(state["opt"]["step"], total=schedule_total)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state["opt"], params, lr_scale)
        metrics = {**metrics, **opt_metrics, "loss_total": loss}
        return {"params": new_params, "opt": new_opt, "ef": ef}, metrics

    return train_step


def jit_train_step(model, sharder, state, batch_keys, **kw):
    """pjit the train step with explicit in/out shardings."""
    step = build_train_step(model, sharder, **kw)
    sspecs = train_state_specs(sharder, state)
    bspecs = sharder.batch_specs(batch_keys)
    mspecs = None  # metrics replicated
    return jax.jit(
        step,
        in_shardings=shardings_for(sharder.mesh, (sspecs, bspecs)),
        out_shardings=shardings_for(sharder.mesh, (sspecs, mspecs)),
        donate_argnums=(0,),
    )


def build_prefill_step(model: Model, sharder: Sharder):
    def prefill(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill


def jit_prefill_step(model, sharder, params, batch_keys, cache):
    pspecs = sharder.param_specs(params)
    bspecs = sharder.batch_specs(batch_keys)
    cspecs = sharder.cache_specs(cache)
    lspec = P(sharder.batch_spec()[0], sharder.vocab_axis())
    has_mem = model.cfg.family in ("encdec", "vlm")
    mem_spec = P(sharder.batch_spec()[0], None, None) if has_mem else None
    return jax.jit(
        build_prefill_step(model, sharder),
        in_shardings=shardings_for(sharder.mesh, (pspecs, bspecs, cspecs)),
        out_shardings=shardings_for(sharder.mesh, (lspec, cspecs, mem_spec)),
        donate_argnums=(2,),
    )


def build_decode_step(model: Model, sharder: Sharder):
    def decode(params, token, pos, cache, memory=None):
        return model.decode_step(params, token, pos, cache, memory)
    return decode


def jit_decode_step(model, sharder, params, cache, *, has_memory: bool):
    pspecs = sharder.param_specs(params)
    cspecs = sharder.cache_specs(cache)
    bd = sharder.batch_spec()[0]
    tok_spec = P(bd)
    lspec = P(bd, sharder.vocab_axis())
    mem_spec = P(bd, None, None) if has_memory else None
    in_sh = (pspecs, tok_spec, tok_spec, cspecs) + ((mem_spec,) if has_memory else ())
    fn = build_decode_step(model, sharder)
    if not has_memory:
        fn = functools.partial(fn, memory=None)
        fn = lambda p, t, ps, c: build_decode_step(model, sharder)(p, t, ps, c, None)
    return jax.jit(
        fn,
        in_shardings=shardings_for(sharder.mesh, in_sh),
        out_shardings=shardings_for(sharder.mesh, (lspec, cspecs)),
        donate_argnums=(3,),
    )
