"""Sharding rules: param/activation/cache PartitionSpecs per profile.

Profiles (cfg.sharding_profile):
  tp       — weights sharded over the `model` axis only (Megatron TP);
             batch over ('pod','data'). For models that fit replicated
             per data shard (<= ~10B params).
  fsdp_tp  — additionally shard the non-TP weight axis over `data`
             (ZeRO-3): per-layer all-gathers inserted by GSPMD. Required
             for the >= 30B configs (fp32 master + Adam state is 12 B/param).

MoE (cfg.moe_sharding):
  ep — expert axis over `model` (E % model == 0, e.g. qwen3 128/16=8);
  tp — d_ff over `model` inside each expert (mixtral: 8 experts < 16).

Small attention-free models (mamba2) replicate weights and spread the
batch over BOTH axes — TP buys nothing at 130M, DP over 256 chips does.

Rules are path-based over the param pytree, so they apply uniformly to
scanned (stacked (G, ...) leaves) and remainder blocks.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

__all__ = ["Sharder", "gemm_partition_specs"]


def gemm_partition_specs(partition: str, axis: str = "model"):
    """((x_spec, w_spec), out_spec) for one mesh-sharded olm GEMM.

    The canonical specs live next to the kernel front-end
    (kernels/online_dot/matmul_sharded — the shard_map wrapper and this
    table must never drift apart); this re-export is the model-layer
    entry point alongside the param/activation/cache rules above.

      m — x rows over `axis`, w replicated, output rows sharded
          (bit-identical per shard to single-device);
      n — w columns over `axis`, output columns sharded (bit-identical);
      k — contraction co-sharded, f32 partials psum'd, output
          replicated (olm_error_bound holds; reduction order differs).
    """
    from repro.kernels.online_dot.matmul_sharded import (
        gemm_partition_specs as _specs)
    return _specs(partition, axis)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


class Sharder:
    def __init__(self, mesh: Mesh, cfg: ModelConfig):
        self.mesh = mesh
        self.cfg = cfg
        self.dp: Tuple[str, ...] = tuple(
            a for a in ("pod", "data") if a in mesh.axis_names)
        self.model_size = mesh.shape["model"]
        self.data_size = int(np.prod([mesh.shape[a] for a in self.dp]))
        self.fsdp = cfg.sharding_profile == "fsdp_tp"
        # mamba2-style tiny models: replicate weights, batch over all axes
        self.replicated = cfg.family == "ssm"
        self._batch_ax: Optional[Tuple[str, ...]] = None

    def set_batch(self, global_batch: int) -> None:
        """Pick the batch-sharding axes as the longest prefix of the DP
        axes (+ model for replicated-weight models) that divides the
        global batch — small serving batches degrade gracefully to fewer
        axes instead of failing divisibility."""
        axes = self.dp + (("model",) if self.replicated else ())
        chosen: Tuple[str, ...] = ()
        size = 1
        for a in axes:
            s = self.mesh.shape[a]
            if global_batch % (size * s) == 0:
                chosen = chosen + (a,)
                size *= s
        self._batch_ax = chosen

    # -------------- helpers --------------
    def _fs(self) -> Optional[str]:
        """The FSDP axis for the non-TP weight dimension ('data' or None).
        Only 'data' (not 'pod') is used so a pod holds a full copy and
        cross-pod traffic stays gradient-only."""
        return "data" if (self.fsdp and "data" in self.mesh.axis_names) else None

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # -------------- params --------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        cfg = self.cfg
        fs = self._fs()
        # scanned leaves carry a leading (G,) axis; `pad` right-aligns the
        # rule so it applies to stacked and unstacked leaves alike
        def pad(spec_dims):
            extra = len(shape) - len(spec_dims)
            return P(*([None] * extra + list(spec_dims)))

        if self.replicated:
            return P(*([None] * len(shape)))
        leaf = path.split("/")[-1]
        parent = path.split("/")[-2] if "/" in path else ""

        # embeddings / unembedding: vocab over model, d over fsdp axis
        if leaf == "table":
            return pad(["model", fs])
        # router: small, replicated
        if leaf == "router":
            return pad([None, None])
        # MoE experts (E, d, f) / (E, f, d) — discriminated by path, the
        # dense MLP uses the same leaf names
        if parent == "moe" or "/moe/" in path:
            if cfg.moe_sharding == "ep" and cfg.n_experts % self.model_size == 0:
                # EP: experts over `model`, FSDP over `data` on d_model.
                # (Replicating expert master+opt over data was tried and
                # refuted: 94 layers x 8 experts x 18.9M x 12 B = 170 GiB
                # per device — §Perf iteration 4.)
                return pad(["model", fs, None])
            return pad([None, fs, "model"]) if leaf in ("wg", "wu") else \
                pad([None, "model", fs])
        # attention projections
        if leaf in ("wq", "wk", "wv"):
            return pad([fs, "model"])
        if leaf == "wo" and parent in ("attn", "cross", "rec"):
            return pad(["model", fs])
        if leaf in ("bq", "bk", "bv"):
            return pad(["model"])
        # dense MLP
        if leaf in ("wg", "wu"):
            return pad([fs, "model"])
        if leaf == "wd":
            return pad(["model", fs])
        # RG-LRU
        if leaf in ("wx", "wy"):
            return pad([fs, "model"])
        if leaf in ("wa", "wi"):
            return pad([None, "model"])
        if leaf in ("ba", "bi", "lam"):
            return pad(["model"])
        if leaf == "conv":
            return pad([None, "model"])
        # SSD (only reached when not `replicated`, e.g. scaled-up ssm)
        if leaf == "win":
            return pad([fs, "model"])
        if leaf == "wout":
            return pad(["model", fs])
        if leaf in ("a_log", "dt_bias", "d_skip", "norm"):
            return pad([None])
        # norms and anything residual-width
        if leaf == "scale":
            return pad([None])
        return P(*([None] * len(shape)))

    def param_specs(self, params) -> Any:
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: self.param_spec(_path_str(path), leaf.shape),
            params)

    # -------------- activations / batch --------------
    def batch_spec(self) -> P:
        """tokens (B, S): batch over DP axes (and model too for replicated
        tiny models, using every chip for DP)."""
        if self._batch_ax is not None:
            return P(self._batch_ax or None, None)
        if self.replicated:
            return P(self.dp + ("model",), None)
        return P(self.dp, None)

    def batch_specs(self, batch_keys) -> Dict[str, P]:
        out = {}
        for k in batch_keys:
            if k in ("tokens", "mask"):
                out[k] = self.batch_spec()
            else:  # frontend embeddings (B, M, d)
                b = self.batch_spec()
                out[k] = P(b[0], None, None)
        return out

    def activation_spec(self, *, seq_sharded: bool = False) -> P:
        """Residual stream (B, S, d)."""
        bd = self.batch_spec()[0]
        if seq_sharded:
            return P(bd, "model", None)
        return P(bd, None, None)

    def vocab_axis(self) -> Optional[str]:
        """Axis for the vocab dim of logits; None when 'model' already
        carries the batch (replicated-weight profile)."""
        bd = self.batch_spec()[0]
        names = (bd,) if isinstance(bd, str) else tuple(bd or ())
        return None if (self.replicated or "model" in names) else "model"

    def logits_spec(self) -> P:
        return P(self.batch_spec()[0], None, self.vocab_axis())

    # -------------- caches --------------
    def cache_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """KV / recurrent cache leaves. Scanned leaves have a leading (G,).
        kv: (..., B, T, Hkv, D); rec h: (..., B, w); ssm h: (..., B,H,P,N)."""
        bd = self.batch_spec()[0]
        leaf = path.split("/")[-1]
        def pad(dims):
            extra = len(shape) - len(dims)
            return P(*([None] * extra + list(dims)))
        if leaf == "len":
            return pad([])
        if self.replicated:
            if leaf in ("k", "v"):
                return pad([bd, None, None, None])
            if leaf == "h":
                return pad([bd, None, None, None]) if len(shape) >= 4 else pad([bd, None])
            if leaf == "conv":
                return pad([bd, None, None])
        if leaf in ("k", "v"):
            # Prefer sharding kv heads over `model`; when the head count
            # does not divide (GQA kv < mesh), shard the cache LENGTH axis
            # instead — GSPMD partitions the softmax/contraction reductions
            # into the partial-softmax combine (all-reduce of (B,H) stats),
            # keeping the decode cache at 1/model_size per device.
            if self.cfg.n_kv_heads % self.model_size == 0:
                return pad([bd, None, "model", None])
            return pad([bd, "model", None, None])
        if leaf == "h":
            if len(shape) >= 4:  # ssm state (..., B, H, P, N)
                return pad([bd, None, None, None])
            return pad([bd, "model"])  # rg-lru (..., B, w)
        if leaf == "conv":
            return pad([bd, None, "model"])
        return P(*([None] * len(shape)))

    def cache_specs(self, cache) -> Any:
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: self.cache_spec(_path_str(path), leaf.shape),
            cache)
