"""Mesh-aware sharding constraints usable from model code.

`constrain(x, *dims)` applies jax.lax.with_sharding_constraint with the
given logical dims, silently dropping axes that do not exist in the
ambient mesh or do not divide the corresponding dimension. Model code can
therefore pin the intended sharding of key boundaries (MoE dispatch,
residual stream) without knowing the mesh — outside any mesh context the
call is a no-op, so single-device tests are unaffected.

Pinning these boundaries is not cosmetic: without them GSPMD falls back to
"involuntary full rematerialization" (replicate + repartition) on the MoE
dispatch gathers, which both bloats compile time and inserts full-tensor
copies in place of the intended all-to-all.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["constrain", "mesh_axes", "dp_axes"]

Dim = Union[None, str, Tuple[str, ...]]


def mesh_axes() -> dict:
    """Axis name -> size of the ambient (abstract) mesh, {} if none."""
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover
        return {}
    if m is None or not m.axis_names:
        return {}
    return dict(zip(m.axis_names, m.axis_sizes))


def dp_axes() -> Tuple[str, ...]:
    ax = mesh_axes()
    return tuple(a for a in ("pod", "data") if a in ax)


def constrain(x: jax.Array, *dims: Dim, allow_uneven: bool = False) -> jax.Array:
    """allow_uneven: keep an axis even when it does not divide the dim —
    legal for internal with_sharding_constraint (GSPMD pads), useful for
    e.g. 56 attention heads over a 16-way model axis."""
    ax = mesh_axes()
    if not ax:
        return x
    spec = []
    for i, d in enumerate(dims):
        if d is None:
            spec.append(None)
            continue
        names = (d,) if isinstance(d, str) else tuple(d)
        names = tuple(n for n in names if n in ax)
        if not names:
            spec.append(None)
            continue
        size = 1
        for n in names:
            size *= ax[n]
        if x.shape[i] % size and not allow_uneven:
            spec.append(None)
            continue
        spec.append(names if len(names) > 1 else names[0])
    return jax.lax.with_sharding_constraint(x, P(*spec))
