"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state. The dry-run forces 512 host
devices via XLA_FLAGS before any jax import (see dryrun.py step 0).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "batch_axes", "MODEL_AXIS"]

MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many real devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the batch (pure DP): ('pod','data') when the
    pod axis exists, else ('data',)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
