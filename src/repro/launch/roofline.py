"""Roofline terms from the compiled dry-run artifact.

Three terms per (arch, mesh), in seconds (TPU v5e per-chip constants):

    compute    = HLO_FLOPs / (chips * 197e12 FLOP/s bf16)
    memory     = HLO_bytes / (chips * 819e9 B/s HBM)
    collective = collective_bytes / (chips * 50e9 B/s per ICI link)

cost_analysis() reports per-device numbers under SPMD partitioning, so
`flops` is already FLOPs-per-chip; we therefore divide the GLOBAL model
FLOPs estimate by chips only in the MODEL_FLOPS ratio, not in the terms.
collective_bytes is parsed from the compiled HLO text: operand bytes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # B/s per chip
ICI_BW = 50e9           # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(", re.I)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type like 'bf16[4,128]' or a tuple thereof."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_CALLSITE_RE = re.compile(
    r"(?:condition|body|to_apply|branch_computations|called_computations|"
    r"calls)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, list]:
    """Computation name -> body lines. A computation header is any
    non-indented line ending in '{' (params may contain nested parens);
    the name is the first %token (or the token after ENTRY)."""
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            toks = line.strip().split()
            name = toks[1] if toks[0] == "ENTRY" and len(toks) > 1 else toks[0]
            cur = name.lstrip("%").split("(")[0].rstrip(",")
            comps[cur] = []
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _line_collective(line):
    m = _COLL_RE.search(line)
    if not m or "-done(" in line:
        return None
    eq = line.find("=")
    if eq < 0 or m.start() < eq:
        return None
    return m.group(1).lower(), _shape_bytes(line[eq + 1:m.start()])


_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _comp_defs(lines) -> Dict[str, list]:
    """name -> result dims (first array shape) for every op in a
    computation body (used to recover dot operand shapes)."""
    defs: Dict[str, list] = {}
    for line in lines:
        s = line.strip()
        if not s.startswith("%") or "=" not in s:
            continue
        name = s[1:s.find("=")].strip().split(" ")[0]
        m = _SHAPE_RE.search(s[s.find("=") + 1:][:160])
        if m:
            defs[name] = [int(x) for x in m.group(2).split(",") if x]
    return defs


def _line_dot_flops(line, defs: Dict[str, list]) -> float:
    """2 * prod(result dims) * prod(lhs contracting dims) for dot ops.
    Operand shapes come from the computation's def map (optimized HLO does
    not inline operand types)."""
    if " dot(" not in line:
        return 0.0
    eq = line.find("=")
    d = line.find(" dot(")
    if eq < 0 or d < eq:
        return 0.0
    res = _SHAPE_RE.search(line[eq + 1:d])
    if not res:
        return 0.0
    rdims = [int(x) for x in res.group(2).split(",") if x]
    ml = re.search(r"%([\w.\-]+)", line[d + 5:])
    ldims = defs.get(ml.group(1), []) if ml else []
    mc = _CDIMS_RE.search(line)
    k = 1
    if mc and ldims:
        for c in (int(x) for x in mc.group(1).split(",") if x):
            if c < len(ldims):
                k *= ldims[c]
    elif ldims:  # canonical dot: last lhs dim contracts
        k = ldims[-1]
    out = 1
    for r in rdims:
        out *= r
    return 2.0 * out * k


# Ops that materialize results to HBM on a TPU backend. The CPU text
# leaves elementwise chains unfused (convert/broadcast/multiply/... would
# dominate a naive count by ~4x) — on TPU those fuse into the consumer,
# so the write-traffic proxy counts only genuinely-materializing ops.
_COUNT_OPS = {
    "fusion", "dot", "convolution", "reduce", "reduce-window", "scatter",
    "gather", "dynamic-slice", "transpose",
    "concatenate", "pad", "sort", "select-and-scatter", "custom-call",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "rng", "rng-bit-generator", "cholesky",
    "triangular-solve",
    # NOT dynamic-update-slice: its result aliases operand 0 in-place on
    # TPU (scan carries / KV-cache writes); the true write is the update
    # slice, which is negligible next to the aliased buffer size.
}


def _line_result_bytes(line) -> float:
    """Result bytes of materializing ops (HBM write-traffic proxy)."""
    s = line.strip()
    if not s.startswith("%") or "=" not in s:
        return 0.0
    rest = s[s.find("=") + 1:].strip()
    par = rest.find("(")
    if par <= 0:
        return 0.0
    sp = rest.rfind(" ", 0, par)
    if sp <= 0:
        return 0.0
    opname = rest[sp + 1:par].lstrip("%").split(".")[0]
    if opname not in _COUNT_OPS:
        return 0.0
    return _shape_bytes(rest[:sp])


def _trip_count(while_line: str, comp_lines, comps) -> int:
    """Trip count of a lax.scan-lowered while.

    The loop bound is an s32 constant; after XLA's while-widening it is
    hoisted into the carry tuple, so we trace the while's input tuple
    operands (one copy-hop deep) for integer constants and take the
    largest plausible one. Fallback: constants in the condition body.
    """
    defs = {}
    for line in comp_lines:
        s = line.strip()
        if s.startswith("%") and "=" in s:
            defs[s.split("=", 1)[0].strip().lstrip("%").split(" ")[0]] = s
    m = re.search(r"while\(%?([\w.\-]+)\)", while_line)
    cands = []
    if m and m.group(1) in defs:
        tup = defs[m.group(1)]
        args = re.findall(r"%([\w.\-]+)", tup.split("(", 1)[-1])
        for a in args:
            d = defs.get(a, "")
            if "copy" in d or "convert" in d:
                inner = re.findall(r"%([\w.\-]+)", d.split("(", 1)[-1])
                d = defs.get(inner[0], "") if inner else d
            if "s32[]" in d or "u32[]" in d:
                for c in _CONST_RE.findall(d):
                    cands.append(int(c))
    mcond = re.search(r"condition=%?([\w.\-]+)", while_line)
    if mcond:
        for line in comps.get(mcond.group(1), []):
            for c in _CONST_RE.findall(line):
                cands.append(int(c))
    good = [c for c in cands if 2 <= c <= 1_000_000]
    return max(good) if good else 1


def hlo_walk(hlo_text: str) -> Dict[str, object]:
    """Walk the HLO call graph from ENTRY, weighting ops inside while-loop
    bodies by the loop trip count (lax.scan over layer groups / micro-
    batches / flash chunks executes its body N times but appears once in
    the text). Accumulates, trip-weighted and per-device:

      * collective bytes per kind (result shapes, `-done` skipped),
      * dot FLOPs (2*M*N*K from inline operand types),
      * result bytes of every real op (HBM write-traffic proxy).
    """
    comps = _split_computations(hlo_text)

    import functools

    @functools.lru_cache(maxsize=None)
    def comp_cost(name: str):
        acc: Dict[str, float] = {}
        cnt = 0
        flops = 0.0
        byts = 0.0
        defs = _comp_defs(comps.get(name, ()))
        for line in comps.get(name, ()):  # type: ignore[arg-type]
            lc = _line_collective(line)
            if lc:
                acc[lc[0]] = acc.get(lc[0], 0.0) + lc[1]
                cnt += 1
            flops += _line_dot_flops(line, defs)
            byts += _line_result_bytes(line)
            m = _CALLSITE_RE.search(line)
            if not m:
                continue
            if " while(" in line:
                mbody = re.search(r"body=%?([\w.\-]+)", line)
                trip = _trip_count(line, comps.get(name, []), comps)
                if mbody:
                    sub, sc, sf, sb = comp_cost(mbody.group(1))
                    for k, v in sub.items():
                        acc[k] = acc.get(k, 0.0) + trip * v
                    cnt += trip * sc
                    flops += trip * sf
                    byts += trip * sb
                continue
            for callee in [c.strip().lstrip("%") for c in m.group(1).split(",")]:
                if callee in comps and callee != name:
                    sub, sc, sf, sb = comp_cost(callee)
                    for k, v in sub.items():
                        acc[k] = acc.get(k, 0.0) + v
                    cnt += sc
                    flops += sf
                    byts += sb
        return acc, cnt, flops, byts

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w.\-]+)", line)
            entry = m.group(1) if m else None
            break
    if entry is None or entry not in comps:
        acc: Dict[str, float] = {}
        cnt = 0
        flops = 0.0
        byts = 0.0
        all_lines = hlo_text.splitlines()
        defs = _comp_defs(all_lines)
        for line in all_lines:
            lc = _line_collective(line)
            if lc:
                acc[lc[0]] = acc.get(lc[0], 0.0) + lc[1]
                cnt += 1
            flops += _line_dot_flops(line, defs)
            byts += _line_result_bytes(line)
    else:
        acc, cnt, flops, byts = comp_cost(entry)
    return {"per_kind": acc, "count": cnt,
            "total_bytes": float(sum(acc.values())),
            "dot_flops": flops, "result_bytes": byts}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    return hlo_walk(hlo_text)


def model_flops(cfg, case) -> float:
    """6*N*D (dense) or 6*N_active*D (MoE) global training FLOPs; forward
    only (2*N*D) for serving kinds."""
    n_params = cfg.param_count()
    if cfg.n_experts:
        dense_share = n_params - cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff
        active = dense_share + cfg.n_layers * cfg.experts_per_token * 3 * cfg.d_model * cfg.d_ff
    else:
        active = n_params
    tokens = case.global_batch * (case.seq_len if case.kind != "decode" else 1)
    mult = 6.0 if case.kind == "train" else 2.0
    return mult * active * tokens


def roofline_terms(cost: Dict, coll: Dict, *, n_chips: int, cfg=None,
                   case=None) -> Dict[str, float]:
    """Three-term roofline, all in seconds.

    cost_analysis() undercounts ops inside lax.scan bodies (counted once,
    executed trip times), so the compute/memory terms use the trip-
    weighted HLO walk (dot_flops / result_bytes), with cost_analysis kept
    as the reported lower bound. The collective term divides by chips
    because per-device HLO collective bytes move over each chip's own
    links in parallel (per-device text == per-chip traffic).
    """
    ca_flops = float(cost.get("flops") or 0.0)
    ca_bytes = float(cost.get("bytes accessed") or 0.0)
    flops = max(ca_flops, float(coll.get("dot_flops") or 0.0))
    byts = max(ca_bytes, float(coll.get("result_bytes") or 0.0))
    cb = float(coll.get("total_bytes") or 0.0)
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byts / HBM_BW,
        "collective_s": cb / ICI_BW,
        "n_chips": n_chips,
        "hlo_dot_flops": flops,
        "hlo_result_bytes": byts,
        "cost_analysis_flops": ca_flops,
        "cost_analysis_bytes": ca_bytes,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom
    if cfg is not None and case is not None:
        mf = model_flops(cfg, case)
        terms["model_flops_global"] = mf
        # per-device useful fraction of compiled compute
        terms["useful_flops_ratio"] = (
            mf / n_chips / flops if flops else None)
    return terms
