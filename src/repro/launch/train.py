"""End-to-end training driver.

Wires together: config registry -> model -> sharder -> pjit train step ->
synthetic data pipeline -> checkpoint manager -> fault tolerance
(preemption guard + straggler watchdog). Runs on whatever devices exist
(CPU smoke: --arch <id> --smoke), and on the production mesh unchanged.

Usage (CPU, ~100M model, few hundred steps — deliverable (b) example):
  PYTHONPATH=src python -m repro.launch.train --arch mamba2_130m --smoke \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.compat import use_mesh
from repro.configs import get_config, smoke_config
from repro.core.numerics import EngineSpec
from repro.data.synthetic import SyntheticLMDataset
from repro.distributed.fault import PreemptionGuard, StragglerWatchdog
from repro.distributed.sharding import Sharder
from repro.distributed.train import (init_train_state, jit_train_step)
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    # Numerics override as an EngineSpec (core/numerics.py): route the
    # training GEMMs through a registered DotEngine mode, optionally
    # mesh-sharded through the shard_map olm front-end.
    ap.add_argument("--dot-mode", default=None,
                    help="DotEngine mode for the run's weight GEMMs "
                         "(e.g. olm16, olm32t16); default: the config's")
    ap.add_argument("--dot-tiling", default=None, choices=("auto",),
                    help="'auto' = shape-aware autotuned grid tiling")
    ap.add_argument("--dot-shard", default=None, choices=("m", "n", "k"),
                    help="shard olm GEMMs over the mesh 'model' axis: "
                         "m/n = output-sharded (bit-identical), k = "
                         "psum'd contraction (within olm_error_bound)")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh(data=len(jax.devices())))
    sharder = Sharder(mesh, cfg)
    sharder.set_batch(args.batch)

    data = SyntheticLMDataset(cfg, args.batch, args.seq, seed=args.seed)
    ckpt = CheckpointManager(Path(args.ckpt_dir) / cfg.name, keep=3)

    with use_mesh(mesh):
        key = jax.random.PRNGKey(args.seed)
        state = init_train_state(model, key)
        start_step = 0
        if args.resume and ckpt.latest_step() is not None:
            start_step = ckpt.latest_step()
            state = ckpt.restore(state)
            print(f"resumed from step {start_step}")
        spec_kw = {}
        if args.dot_mode is not None:
            spec_kw["mode"] = args.dot_mode
        if args.dot_tiling is not None:
            spec_kw["tiling"] = args.dot_tiling
        if args.dot_shard is not None:
            spec_kw["shard"] = args.dot_shard
        engine_spec = EngineSpec(**spec_kw) if spec_kw else None
        step_fn = jit_train_step(
            model, sharder, state, ("tokens",) + (
                ("frames",) if cfg.family == "encdec" else
                ("patches",) if cfg.family == "vlm" else ()),
            opt_cfg=AdamWConfig(lr=args.lr),
            microbatches=args.microbatches,
            compress_grads=args.compress_grads,
            schedule_total=args.steps,
            engine_spec=engine_spec)

        watchdog = StragglerWatchdog(
            on_straggler=lambda s, dt: print(f"  [watchdog] step {s} straggled: {dt:.2f}s"))
        losses = []
        with PreemptionGuard() as guard:
            for step in range(start_step, args.steps):
                batch = {k: jax.numpy.asarray(v)
                         for k, v in data.batch(step).items()}
                watchdog.start()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                watchdog.stop(step)
                losses.append(loss)
                if step % args.log_every == 0 or step == args.steps - 1:
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"lr {float(metrics['lr']):.2e}")
                if (step + 1) % args.ckpt_every == 0 or guard.preempted:
                    ckpt.save(step + 1, state)
                if guard.preempted:
                    print("preempted: checkpoint saved, exiting cleanly")
                    break
        ckpt.save(args.steps, state, block=True)
        ckpt.wait()
        summary = {
            "arch": cfg.name, "steps": len(losses),
            "loss_first": losses[0] if losses else None,
            "loss_last": losses[-1] if losses else None,
            "loss_improved": bool(losses and losses[-1] < losses[0]),
            "stragglers": watchdog.flagged,
        }
        print(json.dumps(summary))
        return summary


if __name__ == "__main__":
    main()
