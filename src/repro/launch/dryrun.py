import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices. Do NOT
replicate this flag anywhere else (smoke tests and benches must see the
single real CPU device).

For each cell this driver:
  1. builds the model + sharder on the requested mesh,
  2. jits the train/prefill/decode step with explicit in/out shardings,
  3. .lower(**ShapeDtypeStructs).compile()   — no array allocation,
  4. records compiled.memory_analysis() (proves it fits),
     compiled.cost_analysis() (FLOPs/bytes for §Roofline), and the
     collective-bytes breakdown parsed from the HLO (launch/roofline.py).

Results land in results/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2_1_8b \
      --shape train_4k [--multi-pod] [--all] [--out results/dryrun]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import use_mesh
from repro.configs import get_config, list_archs
from repro.distributed.sharding import Sharder
from repro.distributed.train import (init_train_state, jit_decode_step,
                                     jit_prefill_step, jit_train_step,
                                     train_state_specs)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_bytes, roofline_terms
from repro.launch.shapes import SHAPES, applicable, input_specs
from repro.models.model import Model


def _microbatches(cfg, case) -> int:
    if case.kind != "train":
        return 1
    big = cfg.param_count() > 20e9
    return 8 if big else 1


def eval_shape_tree(fn, *args):
    return jax.eval_shape(fn, *args)


def _serve_params(params_sh):
    """Serving runs bf16 weights (f32 masters are a training artifact);
    halves serve-time weight memory and FSDP gather bytes."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 and
            len(s.shape) >= 2 else s.dtype),
        params_sh)


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: Path,
             seq_sharding: bool = False) -> dict:
    cfg = get_config(arch)
    case = SHAPES[shape]
    ok, why = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16", "skipped": not ok}
    if not ok:
        rec["skip_reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    sharder = Sharder(mesh, cfg)
    sharder.set_batch(case.global_batch)

    t0 = time.time()
    specs = input_specs(cfg, shape)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    with use_mesh(mesh):
        if case.kind == "train":
            state_sh = eval_shape_tree(
                lambda k: init_train_state(model, k), key)
            mb = _microbatches(cfg, case)
            step = jit_train_step(model, sharder, state_sh,
                                  tuple(specs["batch"].keys()),
                                  microbatches=mb)
            lowered = step.lower(state_sh, specs["batch"])
        elif case.kind == "prefill":
            params_sh = _serve_params(eval_shape_tree(model.init, key))
            cache_sh = eval_shape_tree(
                lambda: model.init_cache(case.global_batch, case.seq_len))
            step = jit_prefill_step(model, sharder, params_sh,
                                    tuple(specs["batch"].keys()), cache_sh)
            lowered = step.lower(params_sh, specs["batch"], cache_sh)
        else:  # decode
            params_sh = _serve_params(eval_shape_tree(model.init, key))
            cache_sh = eval_shape_tree(
                lambda: model.init_cache(case.global_batch, case.seq_len))
            has_mem = cfg.family in ("encdec", "vlm")
            step = jit_decode_step(model, sharder, params_sh, cache_sh,
                                   has_memory=has_mem)
            args = (params_sh, specs["token"], specs["pos"], cache_sh)
            if has_mem:
                args = args + (specs["memory"],)
            lowered = step.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    n_chips = 512 if multi_pod else 256
    rec.update({
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "microbatches": _microbatches(cfg, case),
        "bytes_per_device": {
            "argument": getattr(mem, "argument_size_in_bytes", None),
            "output": getattr(mem, "output_size_in_bytes", None),
            "temp": getattr(mem, "temp_size_in_bytes", None),
            "peak": getattr(mem, "peak_memory_in_bytes", None),
        },
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "collectives": coll,
        "roofline": roofline_terms(cost, coll, n_chips=n_chips,
                                   cfg=cfg, case=case),
    })
    out_dir.mkdir(parents=True, exist_ok=True)
    fn = out_dir / f"{arch}__{shape}__{rec['mesh']}.json"
    fn.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    out = Path(args.out)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape, multi_pod=mp, out_dir=out)
                    if rec.get("skipped"):
                        print(f"SKIP {tag}: {rec['skip_reason']}")
                        continue
                    peak = rec["bytes_per_device"]["peak"]
                    peak_gb = (peak or 0) / 2**30
                    print(f"OK   {tag}: peak {peak_gb:.2f} GiB/dev, "
                          f"flops {rec['flops']:.3g}, "
                          f"coll {rec['collectives']['total_bytes']:.3g} B, "
                          f"compile {rec['compile_s']}s")
                except Exception as e:  # noqa
                    failures += 1
                    print(f"FAIL {tag}: {type(e).__name__}: {e}")
                    traceback.print_exc(limit=3)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
