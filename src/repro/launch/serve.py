"""End-to-end serving driver: continuous batching over a request stream.

Defaults to the paged KV cache (block-table layout, kv_layout="paged");
pass --kv-layout contiguous for the dense-oracle layout, --kv-blocks /
--kv-block-size to size the paged pool, and --prefill-chunk to split
long prompts into decode-interleaved chunks.

Robustness knobs (see serving/engine.py): --max-queue bounds admission
(overflow sheds with finish_reason="rejected"), --deadline-steps gives
every request a scheduler-step budget, --no-preempt restores terminal
cache_full instead of preemption-with-recompute, --degrade-ladder
names a comma-separated downshift ladder of DotEngine modes (rung 0 =
the deployment base mode), and --numerics-check finishes NaN/Inf lanes
with finish_reason="numerics".

Usage (CPU smoke — deliverable (b) example):
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2_1_8b --smoke \
      --requests 12 --slots 4 --max-new 24
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models.model import Model
from repro.serving.engine import Request, ServeEngine
from repro.serving.report import ServeReport


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-layout", choices=("paged", "contiguous"),
                    default="paged")
    ap.add_argument("--kv-block-size", type=int, default=16)
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="usable pool size + 1 (block 0 is the trash "
                         "block); default sizes the pool to ~half of "
                         "slots*max_len worth of tokens")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="split long prompts into chunks of this many "
                         "tokens, interleaved with decode steps")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the admission queue; overflow submits "
                         "finish with reason 'rejected'")
    ap.add_argument("--deadline-steps", type=int, default=None,
                    help="scheduler-step budget per request; expired "
                         "requests finish with reason 'deadline'")
    ap.add_argument("--no-preempt", action="store_true",
                    help="terminal cache_full on block exhaustion "
                         "instead of preemption-with-recompute")
    ap.add_argument("--degrade-ladder", default=None,
                    help="comma-separated DotEngine-mode downshift "
                         "ladder, rung 0 = the base mode (e.g. "
                         "'olm32,olm32t24,olm32t16')")
    ap.add_argument("--numerics-check", action="store_true",
                    help="finish NaN/Inf lanes with reason 'numerics' "
                         "instead of streaming garbage tokens")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("serve driver targets decoder-only archs; "
                         "use examples/ for enc-dec")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    ladder = (args.degrade_ladder.split(",")
              if args.degrade_ladder else None)
    engine = ServeEngine(model, params, slots=args.slots,
                         max_len=args.max_len,
                         kv_layout=args.kv_layout,
                         kv_block_size=args.kv_block_size,
                         kv_blocks=args.kv_blocks,
                         prefill_chunk=args.prefill_chunk,
                         max_queue=args.max_queue,
                         preempt=not args.no_preempt,
                         numerics_check=args.numerics_check,
                         degrade_ladder=ladder)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(4, args.max_len // 4))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new,
                              deadline_steps=args.deadline_steps))
    done = engine.run()
    # One unified summary (serving/report.py): wall-clock latency +
    # finish_reasons at the top level, KV residency under "kv", engine
    # event counters under "counters" — one JSON line per deployment.
    rep = ServeReport.collect(engine, done)
    for r in done[:4]:
        tier = f", tier {r.served_tier}" if r.served_tier else ""
        print(f"req {r.rid}: prompt {len(r.prompt)} toks -> {len(r.output)} "
              f"new ({r.finish_reason}{tier})")
    print(json.dumps(rep))
    assert len(done) == args.requests, "engine dropped requests"
    return rep


if __name__ == "__main__":
    main()
