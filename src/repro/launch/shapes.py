"""Assigned input-shape sets and ShapeDtypeStruct stand-ins (no allocation).

LM transformer shapes are seq_len x global_batch. decode_* / long_* lower
`serve` steps (one new token against a seq_len cache), NOT train_step.
long_500k needs sub-quadratic attention: it runs for the SSM/hybrid/SWA
archs and is skipped for pure full-attention archs (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["SHAPES", "ShapeCase", "applicable", "input_specs", "cells_for"]


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524_288, 1, "decode"),
}

# archs with bounded attention state (SWA window / recurrent) run long_500k
LONG_OK_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    case = SHAPES[shape]
    if case.name == "long_500k":
        if cfg.family in LONG_OK_FAMILIES or cfg.sliding_window is not None:
            return True, ""
        return False, "full quadratic attention: 500k decode infeasible (skip noted in DESIGN.md)"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   {tokens (B,S)}                        -> train_step batch
    prefill: {tokens (B,S)}                        -> prefill batch
    decode:  {token (B,), pos (B,)}                -> decode_step inputs
    plus frontend stubs for encdec (frames) / vlm (patches).
    """
    case = SHAPES[shape]
    B, S = case.global_batch, case.seq_len
    out: Dict[str, Any] = {"case": case}
    if case.kind in ("train", "prefill"):
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                   jnp.float32)
        if cfg.family == "vlm":
            batch["patches"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                    jnp.float32)
        out["batch"] = batch
    else:
        out["token"] = _sds((B,), jnp.int32)
        out["pos"] = _sds((B,), jnp.int32)
        if cfg.family in ("encdec", "vlm"):
            out["memory"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                 jnp.dtype(cfg.compute_dtype))
    return out


def cells_for(cfg: ModelConfig):
    """All applicable (shape_name, reason-if-skipped) for one arch."""
    cells = []
    for name in SHAPES:
        ok, why = applicable(cfg, name)
        cells.append((name, ok, why))
    return cells
