from .adamw import AdamWConfig, adamw_init, adamw_update
from .schedule import cosine_schedule
from .compression import compress_int8, decompress_int8
