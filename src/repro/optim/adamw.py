"""AdamW with decoupled weight decay and global-norm clipping.

Optimizer state is a pytree shaped like the params (m, v in fp32), so the
FSDP sharding rules apply to it unchanged (ZeRO: optimizer state sharded
wherever the master params are).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)))
        for l in jax.tree_util.tree_leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params, lr_scale=1.0
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = opt_state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_ = cfg.b1 * m + (1 - cfg.b1) * g
        v_ = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_ / b1c
        vh = v_ / b2c
        pf = p.astype(jnp.float32)
        pn = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf)
        return pn.astype(p.dtype), m_, v_

    flat, treedef = jax.tree_util.tree_flatten(params)
    gflat = treedef.flatten_up_to(grads)
    mflat = treedef.flatten_up_to(opt_state["m"])
    vflat = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(gflat, mflat, vflat, flat)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": jnp.asarray(lr)}
