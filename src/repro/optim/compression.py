"""Int8 gradient compression with error feedback.

Used on the cross-pod (DCN) gradient all-reduce: gradients are quantized
to int8 with a per-tensor scale before the reduction, and the quantization
residual is fed back into the next step's gradient (error feedback keeps
the long-run bias at zero). This is the distributed-optimization analogue
of the paper's reduced-precision inner products: fewer bits on the wire at
the same converged accuracy.

The quantize/dequantize pair is exact-int8 (validated in tests); the
runtime hook lives in distributed/train.py (compress_grads=True).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "ef_compress_tree"]


def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, error_state):
    """Apply error-feedback int8 compression leaf-wise.

    Returns (decompressed grads to feed the reducer, new error state).
    """
    if error_state is None:
        error_state = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_int8(corrected)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), corrected - deq

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = td.flatten_up_to(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in outs]), td.unflatten([o[1] for o in outs])
