"""Online inner products: pipelined multiplier array + online adder tree.

The paper's target workload: inner products for CNN/matmul accelerators
(Eyeriss PEs, FPGA matmul engines). Each PE multiplies streamed operand
pairs MSDF; product digit streams feed a balanced tree of online adders
(delta_add = 2 per level), so the whole dot product is digit-serial with a
total online delay of

    delta_dot = delta_mul + 2 * ceil(log2 k)

and never waits for any full-precision intermediate.

Normalization: each adder level emits (a + b)/2 to stay in (-1, 1), so the
tree output equals  sum_i x_i y_i / 2^L  with L = ceil(log2 k) (documented
scale, exact).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

from .online_add import online_add
from .online_mul import online_multiply
from .pipeline import PipelineRun, run_pipeline
from .precision import OnlinePrecision

__all__ = ["OnlineDotResult", "online_dot", "online_dot_pipelined"]


@dataclasses.dataclass
class OnlineDotResult:
    digits: List[int]          # SD digits of sum(x_i y_i) / 2^L
    value: float               # decoded value (already includes the 2^-L scale)
    scale_log2: int            # L: result = dot / 2^L
    online_delay: int          # delta_mul + 2 L
    cycles: int                # pipelined cycles to drain all k products
    pipeline: PipelineRun | None = None

    @property
    def dot_value(self) -> float:
        """The actual inner product value (scale removed)."""
        return self.value * (1 << self.scale_log2)


def _tree_reduce(streams: List[List[int]]) -> Tuple[List[int], int]:
    """Reduce SD digit streams pairwise with the online adder; returns the
    final stream and the number of levels (scale log2)."""
    level = 0
    while len(streams) > 1:
        if len(streams) % 2:
            streams = streams + [[0] * len(streams[0])]
        nxt = []
        for a, b in zip(streams[::2], streams[1::2]):
            nxt.append(online_add(a, b))
        streams = nxt
        level += 1
    return streams[0], level


def online_dot(
    xs: Sequence[Sequence[int]],
    ys: Sequence[Sequence[int]],
    cfg: OnlinePrecision | None = None,
) -> OnlineDotResult:
    """Functional online inner product of k SD operand pairs (non-pipelined
    timing; use online_dot_pipelined for the streamed-array timing)."""
    k = len(xs)
    if k == 0 or len(ys) != k:
        raise ValueError("need equal, nonzero operand counts")
    n = len(xs[0])
    if cfg is None:
        cfg = OnlinePrecision(n=n)
    prods = [online_multiply(x, y, cfg).z_digits for x, y in zip(xs, ys)]
    out, levels = _tree_reduce([list(p) for p in prods])
    val = sum(d * 2.0 ** -(i + 1) for i, d in enumerate(out))
    return OnlineDotResult(
        digits=out,
        value=val,
        scale_log2=levels,
        online_delay=cfg.delta + 2 * levels,
        cycles=(cfg.n + cfg.delta + 1) * k,  # non-pipelined (Table III row 3)
    )


def online_dot_pipelined(
    xs: Sequence[Sequence[int]],
    ys: Sequence[Sequence[int]],
    cfg: OnlinePrecision | None = None,
) -> OnlineDotResult:
    """Inner product with the k pairs streamed through the unrolled
    pipelined multiplier (paper's proposed design): the multiplier array
    drains in (n + delta + 1) + (k - 1) cycles (Table III rows 4-5), and
    the adder tree adds 2*ceil(log2 k) cycles of online delay."""
    k = len(xs)
    n = len(xs[0])
    if cfg is None:
        cfg = OnlinePrecision(n=n)
    run = run_pipeline(list(zip(xs, ys)), cfg)
    prods = [t.z_digits for t in run.traces]
    out, levels = _tree_reduce([list(p) for p in prods])
    val = sum(d * 2.0 ** -(i + 1) for i, d in enumerate(out))
    return OnlineDotResult(
        digits=out,
        value=val,
        scale_log2=levels,
        online_delay=cfg.delta + 2 * levels,
        cycles=run.cycles + 2 * int(math.ceil(math.log2(max(k, 2)))),
        pipeline=run,
    )
