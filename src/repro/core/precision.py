"""Precision configuration for the online truncated-precision multiplier.

Implements Eq. (8) of the paper:

    p = ceil((2n + delta + t) / 3)

which gives the reduced working precision (number of fractional bit-slices)
sufficient for a valid selection function with a `t`-fractional-MSD estimate
in the radix-2 online multiplier with a [4:2] redundant adder.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["reduced_precision", "truncation_schedule", "OnlinePrecision"]


def reduced_precision(n: int, delta: int = 3, t: int = 2) -> int:
    """Paper Eq. (8): minimum working fractional bit-slices for n-digit output."""
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    return math.ceil((2 * n + delta + t) / 3)


def truncation_schedule(n: int, p: int, delta: int = 3,
                        t: int = 2) -> "OnlinePrecision":
    """Working-precision schedule of the truncated `olm{n}t{p}` mode
    family: the n-digit array run with only p < n working digits.

    The paper's error profile (Fig. 7) says the per-slice live width
    ramps up along the schedule and back down — so an array asked for p
    output digits of quality simply *is* the Eq. 8 schedule instanced at
    p: fewer digit-recurrence iterations (p + delta instead of n +
    delta), a (k, p) live digit buffer instead of (k, n), and p-digit
    operand grids (a p/n cut in digit operand bytes on the grid matmul
    path; the fused quantize-in-kernel path recodes raw f32 tiles to p
    digits inside the prologue). The returned OnlinePrecision is the
    exact config `olm_matmul(..., n_bits=n, trunc=p)` runs, and the one
    the olmlint analyzer re-proves int32 non-overflow / decode-window
    fit for (repro/analysis — schedule/olm{n}t{p} contract labels).

    Validates delta + 1 <= p < n: p >= delta + 1 is the OnlinePrecision
    floor (the online delay must fit), and p >= n is not a truncation —
    ask for the full mode instead.
    """
    if not delta + 1 <= p < n:
        raise ValueError(
            f"truncated working precision must satisfy delta+1={delta + 1} "
            f"<= p < n; got p={p}, n={n}")
    return OnlinePrecision(n=p, delta=delta, t=t)


@dataclasses.dataclass(frozen=True)
class OnlinePrecision:
    """Numeric configuration of a radix-2 online multiplier instance.

    Attributes:
      n:     output precision in digits (product accurate to ~2^-n).
      delta: online delay (paper uses 3 for radix-2 multiplication).
      t:     fractional MSDs used by the selection function estimate (paper: 2).
      ib:    integer bits of the residual datapath (paper Fig. 7: 2).
      truncated: if True, working precision is p = Eq.(8); else full (n + delta).
      tail_gating: if True, additionally gate slices that can no longer reach
        the selection window (Fig. 7 decreasing tail). Bit-exactness of the
        output under tail gating is property-tested (tests/test_online_mul.py).
      tail_guard: extra slack positions kept live in the tail schedule.
    """

    n: int
    delta: int = 3
    t: int = 2
    ib: int = 2
    truncated: bool = True
    tail_gating: bool = True
    # Tail guard G trades area for accuracy ("decreases according to the
    # error profile", paper §III). Measured max |z - xy| in output ulp /
    # schedule-area saving vs the full design (randomized sweeps, tests):
    #   G=1: 1.03-1.40 ulp / 39-44%      G=2: 0.73-0.93 ulp / 35-41%
    #   G=3: 0.59-0.71 ulp / 31-39%      no tail: ~0.5 ulp / ~16%
    # Default G=2 keeps every n at sub-ulp error with paper-band savings.
    tail_guard: int = 2

    def __post_init__(self):
        if self.n < self.delta + 1:
            raise ValueError(f"n must exceed online delay; got n={self.n} delta={self.delta}")

    @property
    def p(self) -> int:
        """Working fractional precision (bit-slices) of the datapath."""
        full = self.n + self.delta
        if not self.truncated:
            return full
        return min(reduced_precision(self.n, self.delta, self.t), full)

    @property
    def steps(self) -> int:
        """Total iterations: delta initialization + n digit-producing steps."""
        return self.n + self.delta

    @property
    def pipeline_latency(self) -> int:
        """Cycles to first result of a pipelined stream (paper Table III)."""
        return self.n + self.delta + 1

    def stream_cycles(self, k: int) -> int:
        """Cycles to process k products through the unrolled pipeline."""
        return self.pipeline_latency + (k - 1)
