"""Signed-digit (SD) codec utilities for radix-2 online arithmetic.

A value x in (-1, 1) is represented by n signed digits x_1..x_n, each in
{-1, 0, +1}, with x = sum_i x_i * 2^-i. Hardware encodes each digit as a
(x+, x-) bit pair with x_i = x+ - x- (borrow-save). These helpers convert
between dyadic fractions, digit vectors, and scaled integers, and implement
the OTFC (on-the-fly conversion) algorithm of Ercegovac & Lang used by the
multiplier to keep x[j]/y[j] in conventional two's-complement form without
carry propagation.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

import numpy as np

__all__ = [
    "int_to_digits",
    "frac_to_digits",
    "digits_to_int",
    "digits_to_frac",
    "digits_to_nonredundant",
    "random_digits",
    "OTFC",
]


def int_to_digits(value: int, n: int) -> List[int]:
    """Encode integer `value` (|value| < 2^n) as n SD digits of value * 2^-n.

    Uses the sign-magnitude encoding: binary digits of |value| with the sign
    applied to every digit. This is always a valid SD representation.
    """
    if abs(value) >= 2**n:
        raise ValueError(f"|value| must be < 2^{n}, got {value}")
    sign = 1 if value >= 0 else -1
    mag = abs(value)
    return [sign * ((mag >> (n - i)) & 1) for i in range(1, n + 1)]


def frac_to_digits(x: float, n: int) -> List[int]:
    """Encode x in (-1, 1) as n SD digits (rounding to nearest 2^-n)."""
    v = int(round(x * (1 << n)))
    v = max(-(2**n) + 1, min(2**n - 1, v))
    return int_to_digits(v, n)


def digits_to_int(digits: Sequence[int], n: int | None = None) -> int:
    """Value of the digit vector scaled by 2^n (exact integer)."""
    if n is None:
        n = len(digits)
    acc = 0
    for i, d in enumerate(digits, start=1):
        acc += d * (1 << (n - i))
    return acc


def digits_to_frac(digits: Sequence[int]) -> float:
    return digits_to_int(digits, len(digits)) / float(1 << len(digits))


def digits_to_nonredundant(digits: Sequence[int]) -> List[int]:
    """Convert SD digits to conventional {0,1} bits of the two's complement
    representation of the same value (via exact integer round-trip)."""
    n = len(digits)
    v = digits_to_int(digits, n)
    return int_to_digits(abs(v), n) if v >= 0 else int_to_digits(v, n)


def random_digits(rng: np.random.Generator, n: int, batch: int | None = None):
    """Uniform random SD digit vectors in {-1,0,1}^n (batch x n if batch)."""
    shape = (n,) if batch is None else (batch, n)
    return rng.integers(-1, 2, size=shape)


class OTFC:
    """On-the-fly conversion of an MSDF signed-digit stream to conventional
    two's-complement form (Ercegovac & Lang 1987).

    Maintains Q (the converted prefix) and QM (= Q - ulp) so that appending a
    digit never needs carry propagation:

        d = +1:  Q' = Q.1   QM' = Q.0      (append bit to the chosen register)
        d =  0:  Q' = Q.0   QM' = QM.1
        d = -1:  Q' = QM.1  QM' = QM.0

    Register values are tracked as integers scaled by 2^j after j digits.
    """

    def __init__(self):
        self.q = 0
        self.qm = -1
        self.j = 0

    def append(self, d: int) -> None:
        if d not in (-1, 0, 1):
            raise ValueError(f"digit must be in {{-1,0,1}}, got {d}")
        q, qm = self.q, self.qm
        if d == 1:
            self.q, self.qm = 2 * q + 1, 2 * q
        elif d == 0:
            self.q, self.qm = 2 * q, 2 * qm + 1
        else:
            self.q, self.qm = 2 * qm + 1, 2 * qm
        self.j += 1

    def value(self) -> int:
        """Converted value scaled by 2^j (exact)."""
        return self.q

    @staticmethod
    def convert(digits: Iterable[int]) -> int:
        conv = OTFC()
        for d in digits:
            conv.append(d)
        return conv.value()
