"""Gate-level area / latch / power model of the pipelined online multiplier.

Reproduces the *methodology* of the paper's synthesis tables: relative area
in NAND-gate-equivalents using the MCNC gate-cost dictionary quoted by the
paper (BUFF 0.0, NOT 0.67, NAND 1.0, NOR 1.0, AND 1.33, OR 1.33, XOR 2.0,
XNOR 1.66), latch counts per unrolled stage, and a zero-delay switching
power proxy driven by *measured* register activity from the bit-exact
simulator. The paper's own Yosys/SIS numbers are kept alongside as the
comparison target (benchmarks print model vs paper).

Stage inventories follow paper Fig. 6:
  (a) initialization stages: CA-REGs (OTFC), SELECTORs, [4:2] CSA — no
      V / M / SELM;
  (b) recurrence stages: everything;
  (c) last-delta stages: no input-side modules (CA-REG append, SELECTOR);
  (+) one output register stage.

All widths come from the Fig. 7 schedule T(j) (core.online_mul.
working_precision), so the truncated design's savings *emerge* from the
schedule rather than being hard-coded.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .online_mul import working_precision
from .precision import OnlinePrecision

__all__ = [
    "GATE_AREA",
    "StageCost",
    "MultiplierCost",
    "online_multiplier_cost",
    "serial_parallel_cost",
    "array_multiplier_cost",
    "nonpipelined_online_cost",
    "truncated_delta",
    "PAPER_TABLE1",
    "PAPER_TABLE2",
]

# MCNC relative gate areas (from the paper, after [13]).
GATE_AREA: Dict[str, float] = {
    "BUFF": 0.0, "NOT": 0.67, "NAND": 1.0, "NOR": 1.0,
    "AND": 1.33, "OR": 1.33, "XOR": 2.0, "XNOR": 1.66,
}

# Composite cell costs in gate-equivalents (classic static-CMOS mappings).
LATCH_AREA = 4 * GATE_AREA["NAND"]                         # SR-latch pair
FA_AREA = 2 * GATE_AREA["XOR"] + 2 * GATE_AREA["AND"] + GATE_AREA["OR"]
COMP42_AREA = 2 * FA_AREA                                  # [4:2] = 2 FAs
MUX2_AREA = 2 * GATE_AREA["AND"] + GATE_AREA["OR"] + GATE_AREA["NOT"]
SELECTOR_AREA = GATE_AREA["XOR"] + GATE_AREA["AND"]        # +-x / 0 per slice
OTFC_MUX_AREA = MUX2_AREA                                  # per register slice

# Power proxy: paper reports SIS zero-delay power at 20 MHz / 5 V; across
# its own tables power/area is ~9.8 uW per gate-equivalent for the online
# designs. We expose that constant so the model lands in paper units.
POWER_PER_AREA_ACTIVITY = 9.82  # uW per gate-eq at activity factor 1.0


@dataclasses.dataclass
class StageCost:
    stage: int
    kind: str        # init | recur | last | out
    slices: int      # live fractional slices T(j)
    latches: int
    area: float


@dataclasses.dataclass
class MultiplierCost:
    name: str
    n: int
    latches: int
    area: float
    power: float
    stages: List[StageCost] = dataclasses.field(default_factory=list)

    def row(self) -> Dict[str, float]:
        return {"latches": self.latches, "area": round(self.area, 2),
                "power": round(self.power, 1)}


def _stage_cost(cfg: OnlinePrecision, s: int) -> StageCost:
    """Cost of unrolled stage s (running step j = s - delta)."""
    d, ib, t = cfg.delta, cfg.ib, cfg.t
    n_stages = cfg.steps
    if s >= n_stages:  # output register stage
        return StageCost(s, "out", 0, latches=2 * cfg.n // cfg.n + 2, area=2 * LATCH_AREA)
    j = s - d
    T = working_precision(cfg, j)
    w_width = T + ib
    kind = "init" if j < 0 else ("last" if j >= cfg.n - d else "recur")
    has_input = j < cfg.n - d   # last-delta stages receive no digits
    has_output = j >= 0         # init stages produce no digit

    latches = 0
    area = 0.0
    # Residual registers: carry-save pair, w_width wide (always present).
    latches += 2 * w_width
    area += 2 * w_width * LATCH_AREA
    # [4:2] CSA across the live width (init accumulates appends too).
    area += w_width * COMP42_AREA
    if has_input:
        # CA-REG x/y: OTFC dual registers (Q, QM) + per-slice load muxes,
        # and two SELECTOR slices feeding the CSA.
        latches += 4 * T
        area += 4 * T * LATCH_AREA + 2 * T * OTFC_MUX_AREA
        area += 2 * T * SELECTOR_AREA
        # incoming digit pipeline registers (x,y as SD bit pairs)
        latches += 2
        area += 2 * LATCH_AREA
    if has_output:
        # V: short CPA over the ib + t selection window, SELM decision
        # logic, M subtract slice, z-digit register.
        cpa_w = ib + t
        area += cpa_w * FA_AREA
        area += 8.0                        # SELM
        area += FA_AREA + MUX2_AREA        # M block
        latches += 2
        area += 2 * LATCH_AREA
    return StageCost(s, kind, T, latches, area)


def online_multiplier_cost(
    cfg: OnlinePrecision, *, activity: float = 1.0, name: str | None = None
) -> MultiplierCost:
    """Area/latch/power model of the pipelined online multiplier.

    `activity` is the measured switching-activity factor relative to the
    full design (from core.pipeline register-flip counts); the power proxy
    is area * activity * POWER_PER_AREA_ACTIVITY.
    """
    stages = [_stage_cost(cfg, s) for s in range(cfg.steps + 1)]
    latches = sum(st.latches for st in stages)
    area = sum(st.area for st in stages)
    power = area * activity * POWER_PER_AREA_ACTIVITY
    label = name or ("olm-pipelined-reduced" if cfg.truncated else "olm-pipelined-full")
    return MultiplierCost(label, cfg.n, latches, area, power, stages)


def truncated_delta(n: int, p: int) -> Dict[str, float]:
    """Activity / area / latency delta of the truncated olm{n}t{p} tier
    vs the same-width full mode, mirroring the paper's Table I
    comparison axis: both sides are Eq. 8 / Fig. 7 schedules, the tier
    simply instanced at p output digits.

    The activity proxy is total live slices across the unrolled stages
    (sum of T(j) — the registers that can flip each cycle); latency is
    pipeline cycles to first result (n + delta + 1 vs p + delta + 1).
    Returned dict keys: full_/trunc_ {area, latches, power, activity,
    latency} plus {area, power, activity}_save_pct and latency_delta.
    """
    full = online_multiplier_cost(OnlinePrecision(n=n))
    trunc = online_multiplier_cost(OnlinePrecision(n=p),
                                   name=f"olm{n}t{p}")
    act_full = sum(st.slices for st in full.stages)
    act_trunc = sum(st.slices for st in trunc.stages)

    def pct(a: float, b: float) -> float:
        return round(100.0 * (1.0 - b / a), 2) if a else 0.0

    return {
        "full_area": round(full.area, 2),
        "trunc_area": round(trunc.area, 2),
        "area_save_pct": pct(full.area, trunc.area),
        "full_latches": full.latches,
        "trunc_latches": trunc.latches,
        "full_power": round(full.power, 1),
        "trunc_power": round(trunc.power, 1),
        "power_save_pct": pct(full.power, trunc.power),
        "full_activity": act_full,
        "trunc_activity": act_trunc,
        "activity_save_pct": pct(act_full, act_trunc),
        "full_latency": OnlinePrecision(n=n).pipeline_latency,
        "trunc_latency": OnlinePrecision(n=p).pipeline_latency,
        "latency_delta": (OnlinePrecision(n=n).pipeline_latency
                          - OnlinePrecision(n=p).pipeline_latency),
    }


def nonpipelined_online_cost(n: int) -> MultiplierCost:
    """Single-stage (iterative) online multiplier: one recurrence stage's
    hardware at full width, reused for n + delta cycles."""
    cfg = OnlinePrecision(n=n, truncated=False, tail_gating=False)
    full = _stage_cost(cfg, cfg.delta + 1)  # a full-width recurrence stage
    # control/counter overhead for the iterative version
    latches = full.latches + 8
    area = full.area + 8 * LATCH_AREA + 10.0
    power = area * POWER_PER_AREA_ACTIVITY
    return MultiplierCost("online-iterative", n, latches, area, power)


def serial_parallel_cost(n: int) -> MultiplierCost:
    """Serial-parallel multiplier [Bewick94]: n AND gates + n-bit CPA adder
    row + (2n+1)-bit accumulator/shift registers + control."""
    latches = 6 * n + 5
    area = n * GATE_AREA["AND"] + n * FA_AREA + latches * LATCH_AREA + 12.0
    power = area * POWER_PER_AREA_ACTIVITY
    return MultiplierCost("serial-parallel", n, latches, area, power)


def array_multiplier_cost(n: int) -> MultiplierCost:
    """Baugh-Wooley two's complement array multiplier: ~n^2 AND + n(n-1) FA
    cells, I/O registers only (combinational core)."""
    latches = 4 * n
    area = n * n * GATE_AREA["AND"] + n * (n - 1) * FA_AREA + latches * LATCH_AREA
    # combinational arrays burn proportionally less clocked power per area
    power = area * 0.66 * POWER_PER_AREA_ACTIVITY
    return MultiplierCost("array", n, latches, area, power)


# ------------------------- paper's own numbers -------------------------
# Table I: pipelined online multiplier, full vs reduced working precision.
PAPER_TABLE1 = {
    "latches": {"full": {8: 432, 16: 1734, 24: 2906, 32: 4844},
                "reduced": {8: 315, 16: 976, 24: 1906, 32: 3162}},
    "area": {"full": {8: 2629.39, 16: 10529.32, 24: 21556.31, 32: 36217.59},
             "reduced": {8: 1947.91, 16: 6432.94, 24: 12461.77, 32: 20133.69}},
    "power": {"full": {8: 25812.80, 16: 95179.70, 24: 194340.50, 32: 325686.80},
              "reduced": {8: 18695.50, 16: 62720.40, 24: 122039.00, 32: 199687.70}},
}

# Table II: 8-bit comparison across multiplier families.
PAPER_TABLE2 = {
    "serial-parallel": {"latches": 53, "area": 287.57, "power": 2808.3},
    "array": {"latches": 32, "area": 484.59, "power": 3203.9},
    "online-iterative": {"latches": 62, "area": 313.65, "power": 3332.5},
    "olm-pipelined-full": {"latches": 432, "area": 2629.39, "power": 25812.8},
    "olm-pipelined-reduced": {"latches": 315, "area": 1947.91, "power": 18695.5},
}
