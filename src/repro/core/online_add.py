"""Radix-2 online signed-digit addition (online delay 2).

MSDF digit-serial addition of two SD streams, used to chain online
multipliers into inner-product trees (the paper's target workload: the
product digits of each multiplier feed an online adder tree after only
delta_mul + 2*ceil(log2 k) cycles of total online delay).

Digit-set closure needs one digit of lookahead (hence delta = 2). With
e_k = x_k + y_k in {-2..2}:

    t_k = +1  if e_k >= 2 or (e_k == +1 and e_{k+1} >= 0)
    t_k = -1  if e_k <= -2 or (e_k == -1 and e_{k+1} <  0)
    t_k =  0  otherwise
    w_k = e_k - 2 t_k            in {-1, 0, +1}
    z_k = w_k + t_{k+1}          in {-1, 0, +1}   (proved: no collision)

z_k depends on digits up to position k+2, so the adder emits digit k two
cycles after receiving position-k inputs.
"""
from __future__ import annotations

from typing import List, Sequence

__all__ = ["online_add", "OnlineAdder"]

DELTA_ADD = 2


def _transfer(e_k: int, e_next: int) -> int:
    if e_k >= 2 or (e_k == 1 and e_next >= 0):
        return 1
    if e_k <= -2 or (e_k == -1 and e_next < 0):
        return -1
    return 0


class OnlineAdder:
    """Streaming form: push one (x_k, y_k) digit pair per cycle, pop the
    output digit for position k - DELTA_ADD (None during the delay)."""

    def __init__(self):
        self._e: List[int] = []   # pending digit sums (window of 2)
        self._w_prev: int | None = None
        self._k = 0

    def push(self, x_k: int, y_k: int) -> int | None:
        self._e.append(x_k + y_k)
        self._k += 1
        if len(self._e) < 2:
            return None
        e_k, e_next = self._e[0], self._e[1]
        t_k = _transfer(e_k, e_next)
        w_k = e_k - 2 * t_k
        out = None
        if self._w_prev is not None:
            out = self._w_prev + t_k
        self._w_prev = w_k
        self._e.pop(0)
        return out

    def flush(self) -> List[int]:
        """Feed two zero pairs to drain the delay line; returns last digits."""
        outs = []
        for _ in range(DELTA_ADD):
            o = self.push(0, 0)
            if o is not None:
                outs.append(o)
        return outs


def online_add(x_digits: Sequence[int], y_digits: Sequence[int]) -> List[int]:
    """Add two aligned n-digit SD fractions; returns n+2 SD digits of the sum
    scaled by 1/2 (one extra integer position folded in), i.e.

        sum_i out_i 2^-i  ==  (x + y) / 2

    The /2 pre-scaling keeps the result in (-1, 1) for any SD inputs, which
    is how the inner-product tree normalizes each reduction level.
    """
    n = len(x_digits)
    if len(y_digits) != n:
        raise ValueError("operands must have equal digit counts")
    # Scale by 1/2 = shift digits one position right; position 1 becomes 0 pad.
    xs = [0] + list(x_digits)
    ys = [0] + list(y_digits)
    adder = OnlineAdder()
    out: List[int] = []
    for xk, yk in zip(xs, ys):
        o = adder.push(xk, yk)
        if o is not None:
            out.append(o)
    out.extend(adder.flush())
    assert len(out) == n + 1
    # Append one more exact digit slot (delay line emits n+1 of n+1 inputs);
    # pad to n+2 for callers that track the full significance range.
    out.append(0)
    return out
