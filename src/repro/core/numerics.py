"""DotEngine: pluggable matmul numerics for the whole model stack.

A mode registry replaces the old string-if chain: every numerics choice
is a registered `DotMode` carrying its implementation plus the
error/cost documentation the README mode table and benchmarks surface.

Registered modes:

  native   — dot in the model's compute dtype (bf16 on TPU); baseline.
  tpmm16 / tpmm8 — the paper's truncated-precision inner products
    (kernels/tpmm): operands decomposed into digit planes, plane pairs
    beyond the significance cutoff never computed. n_bits = 16 / 8.
  olm32 / olm24 / olm16 / olm8 — the paper's own inner-product array
    (kernels/online_dot via its matmul front-end) at every
    configs/olm_array.ARRAY_PRECISIONS width: K-lane online multipliers
    feeding a digit-serial online adder tree, matmul tiles quantized to
    signed-digit grids, digit streams decoded and accumulated in f32.
    n = 8/16 decode on the exact plain-f32 path; n = 24/32 stream past
    the 24-digit f32 window and take the wide decode (int64 accumulator
    under x64, two-limb f32 otherwise — kernels/common.decode_policy).
    Every fused kernel path is bit-identical to the pure-jnp oracle and
    bounded by kernels/online_dot/matmul.olm_error_bound.
  olm{n}t{p} — the truncated working-precision family (TRUNCATED_SPECS;
    the paper's headline reduced-activities trick as a throughput/
    quality tier): the n-digit mode run at p < n working digits —
    p-digit operand grids (p/n of the full mode's digit operand bytes),
    p + delta recurrence iterations, a (k, p) live digit buffer — with
    the bounded extra error documented by olm_error_bound's truncation
    term. Serving exposes these as per-request quality tiers
    (serving/engine.py) and per-layer assignments (DotEngine.layer_modes).

The engine is threaded through every dense, attention and MoE matmul, so
the paper's technique is a first-class numerics choice per model config,
not a bolted-on demo. einsum falls back to native numerics for the
attention contractions (their operands are activations on both sides;
the digit modes target the weight-bearing GEMMs, which dominate FLOPs).

Weight dtype: only the `native` mode casts weights to the activation
compute dtype. The digit modes quantize straight from the stored dtype —
fp32 master weights under training keep their full mantissa into the
digit/plane decomposition instead of being rounded through bf16 first.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp

__all__ = ["DotEngine", "DotMode", "EngineSpec", "register_mode",
           "resolve_engine", "TRUNCATED_SPECS"]

# The registered truncated working-precision modes, as (n, p) pairs:
# mode `olm{n}t{p}` is the n-digit array run at p working digits
# (core.precision.truncation_schedule; p must satisfy delta+1 <= p < n).
# This tuple is the single source the mode registration below,
# configs/olm_array.TRUNCATED_MODES, the olmlint analyzer sweep
# (repro/analysis), and the truncated bench/check_bench gate all derive
# from — adding a pair here registers the mode AND brings it under the
# static int32-overflow / decode-window / VMEM proofs automatically.
# olm32t16 is the throughput pick: its 16-digit work stream fits the
# plain-f32 decode window again, dropping the wide two-limb decode the
# full olm32 mode needs.
TRUNCATED_SPECS: Tuple[Tuple[int, int], ...] = (
    (16, 12), (16, 10), (32, 24), (32, 20), (32, 16))


@dataclasses.dataclass(frozen=True)
class DotMode:
    """One registered numerics mode: implementation + trade-off docs."""
    name: str
    summary: str
    error: str     # documented accuracy vs the exact f32 matmul
    cost: str      # documented compute/area trade-off
    fn: Callable[["DotEngine", jax.Array, jax.Array], jax.Array]


_MODES: Dict[str, DotMode] = {}


def register_mode(name: str, *, summary: str, error: str, cost: str):
    """Register a DotEngine mode. The decorated function receives
    (engine, x (..., K), w (K, N)) and returns (..., N). Names are
    single-assignment: silently swapping the implementation under an
    existing mode would change every model built with it."""
    def deco(fn):
        if name in _MODES:
            raise ValueError(f"DotEngine mode {name!r} already registered")
        _MODES[name] = DotMode(name, summary, error, cost, fn)
        return fn
    return deco


@register_mode(
    "native",
    summary="einsum in the model compute dtype (bf16 on TPU)",
    error="exact at compute dtype (bf16 rounding only)",
    cost="full-precision MXU matmul; baseline")
def _native_dot(eng: "DotEngine", x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))


def _lowered_dot(eng: "DotEngine", x: jax.Array, w: jax.Array,
                 matmul_fn, n_bits: int) -> jax.Array:
    """Shared digit-mode lowering: flatten the lead axes onto a 2-D tile,
    hand the weights to the kernel front-end in their stored precision
    (f32 — never pre-rounded through the activation dtype), and restore
    the activation shape/dtype on the way out."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    out = matmul_fn(x.reshape(-1, K), w.astype(jnp.float32), n_bits=n_bits,
                    use_pallas=eng.use_pallas, interpret=eng.interpret)
    return out.reshape(*lead, w.shape[-1]).astype(x.dtype)


def _tpmm_dot(eng: "DotEngine", x: jax.Array, w: jax.Array,
              n_bits: int) -> jax.Array:
    from repro.kernels.tpmm.ops import tpmm
    return _lowered_dot(eng, x, w, tpmm, n_bits)


@register_mode(
    "tpmm16",
    summary="truncated digit-plane matmul, 16-bit significance",
    error="~6e-4 relative (n-bit plane truncation, tested)",
    cost="10/16 plane-pair MXU matmuls (37.5% MXU ops saved)")
def _tpmm16(eng, x, w):
    return _tpmm_dot(eng, x, w, 16)


@register_mode(
    "tpmm8",
    summary="truncated digit-plane matmul, 8-bit significance",
    error="~8e-2 relative (n-bit plane truncation, tested)",
    cost="3/4 plane-pair MXU matmuls (25% MXU ops saved)")
def _tpmm8(eng, x, w):
    return _tpmm_dot(eng, x, w, 8)


def _olm_dot(eng: "DotEngine", x: jax.Array, w: jax.Array,
             n_bits: int, trunc: Optional[int] = None) -> jax.Array:
    import functools
    import math

    from repro.kernels.online_dot.matmul import olm_matmul
    # Grid-kernel tuning knobs ride on the engine (None = the kernel
    # defaults): k_tile is the array width per K chunk, block_m/block_n
    # the output tile the Pallas grid reuses operand digit grids across.
    tiling = {k: v for k, v in (("k_tile", eng.k_tile),
                                ("block_m", eng.block_m),
                                ("block_n", eng.block_n)) if v is not None}
    auto = eng.tiling == "auto" and eng.use_pallas is not False
    sharded = eng.mesh is not None and eng.shard is not None
    if trunc is not None:
        tiling["trunc"] = trunc
    if sharded:
        # Mesh-sharded dispatch: hand the GEMM to the shard_map front-end
        # with the same knobs. tiling="auto" is resolved INSIDE the
        # sharded wrapper against the per-shard local shapes, so a
        # sharded GEMM hits the same autotuner bucket as an equivalent
        # single-device GEMM of the shard size (pinned knobs still win).
        from repro.kernels.online_dot.matmul_sharded import olm_matmul_sharded
        fn = functools.partial(
            olm_matmul_sharded, mesh=eng.mesh, partition=eng.shard,
            axis=eng.shard_axis, tiling="auto" if auto else None, **tiling)
        return _lowered_dot(eng, x, w, fn, n_bits)
    if auto:
        # Shape-aware autotuned tiling per GEMM (shapes are static at
        # trace time, so the lookup runs on the host during tracing).
        # Explicitly pinned engine knobs win over the autotuner. With
        # use_pallas=False the engine is certain to take the broadcast
        # oracle, which ignores block shapes (and auto's k_tile is the
        # pinned default anyway) — skip the lookup rather than pretend
        # it does something. Truncated modes key their own cache bucket
        # (b{n}t{p}) so they never share entries with the full mode.
        from repro.kernels.online_dot.tuning import get_tiling
        auto = get_tiling(math.prod(x.shape[:-1]), w.shape[-1],
                          x.shape[-1], n_bits, trunc=trunc)
        tiling = {**auto, **tiling}
    fn = functools.partial(olm_matmul, **tiling) if tiling else olm_matmul
    return _lowered_dot(eng, x, w, fn, n_bits)


@register_mode(
    "olm16",
    summary="fused online inner-product array, 16-digit operands",
    error="<= k_tile * 3.1 ulp @ 2^-16 per K-tile (olm_error_bound)",
    cost="Eq.8-truncated digit-serial array; grid-tiled operand reuse "
         "(digit-grid traffic / min(block_m, block_n))")
def _olm16(eng, x, w):
    return _olm_dot(eng, x, w, 16)


@register_mode(
    "olm8",
    summary="fused online inner-product array, 8-digit operands",
    error="<= k_tile * 3.1 ulp @ 2^-8 per K-tile (olm_error_bound)",
    cost="Eq.8-truncated digit-serial array; grid-tiled operand reuse "
         "(digit-grid traffic / min(block_m, block_n))")
def _olm8(eng, x, w):
    return _olm_dot(eng, x, w, 8)


@register_mode(
    "olm24",
    summary="fused online inner-product array, 24-digit operands "
            "(wide two-limb/int64 stream decode)",
    error="<= k_tile * (3.1 @ 2^-24 + (T+1) @ 2^-26) per K-tile "
          "(olm_error_bound wide term)",
    cost="Eq.8-truncated digit-serial array at 24 digits; same grid-"
         "tiled reuse, 1.5x the olm16 digit traffic on the host path")
def _olm24(eng, x, w):
    return _olm_dot(eng, x, w, 24)


@register_mode(
    "olm32",
    summary="fused online inner-product array, 32-digit operands "
            "(wide two-limb/int64 stream decode; oracle path x64-scoped)",
    error="<= k_tile * (3.1 @ 2^-32 + (T+1) @ 2^-26) per K-tile "
          "(olm_error_bound wide term)",
    cost="Eq.8-truncated digit-serial array at 32 digits; same grid-"
         "tiled reuse, 2x the olm16 digit traffic on the host path")
def _olm32(eng, x, w):
    return _olm_dot(eng, x, w, 32)


def _register_truncated_modes() -> None:
    """Register every TRUNCATED_SPECS pair as mode `olm{n}t{p}`: the
    n-digit array run at p working digits (truncation_schedule). The
    p-digit kernel path is bit-identical to the olm{p} oracle by
    construction; what the family adds over "just use olm{p}" is the
    quality-tier contract — a documented error bound relative to the
    n-digit parent (olm_error_bound's truncation term), its own tuning
    bucket, and the serving engine's per-request tier selection."""
    for n, p in TRUNCATED_SPECS:
        wide = "wide two-limb/int64" if p > 16 else "exact plain-f32"
        error = (f"<= k_tile * 3.1 * (2^-{n} + 2^-{p}) per K-tile "
                 "(olm_error_bound truncation term)")
        if p > 16:
            error = error[:-1] + " + wide term)"
        register_mode(
            f"olm{n}t{p}",
            summary=f"truncated olm{n}: {p} working digits "
                    f"({wide} stream decode)",
            error=error,
            cost=f"p/n = {p}/{n} of olm{n}'s digit operand bytes and "
                 f"recurrence iterations; pipeline latency {p + 4} vs "
                 f"{n + 4} cycles (hwmodel.truncated_delta)")(
            functools.partial(_olm_dot, n_bits=n, trunc=p))


_register_truncated_modes()


@dataclasses.dataclass(frozen=True)
class DotEngine:
    mode: str = "native"          # any registered mode, see DotEngine.modes()
    interpret: bool = True        # Pallas interpret mode (CPU container)
    use_pallas: bool = False      # jnp oracle by default inside big models
    # olm grid-kernel tuning (None = kernel defaults; ignored by other
    # modes): K lanes per adder tree, and the (block_m, block_n) output
    # tile whose BlockSpecs set the digit-grid reuse factor.
    k_tile: Optional[int] = None
    block_m: Optional[int] = None
    block_n: Optional[int] = None
    # tiling="auto" resolves (block_m, block_n) per GEMM shape through
    # the kernels/online_dot/tuning autotuner (measured-or-heuristic,
    # persistent cache) instead of one static default; explicitly set
    # knobs above still win. Numerics are unchanged: block shapes are
    # bit-invariant, and the tuner pins k_tile (the one knob that IS a
    # numerics parameter) to the kernel default — only an explicit
    # k_tile= here changes it.
    tiling: Optional[str] = None
    # Per-layer precision assignment: {"attn" | "mlp" | "head": mode}
    # overrides for the weight-bearing GEMM roles. The model stack calls
    # for_role() at each site, so e.g. layer_modes={"head": "olm32",
    # "mlp": "olm32t20"} keeps the lm_head at full precision while the
    # MLPs take the truncated throughput tier (ROADMAP: attention vs MLP
    # vs lm_head assignment). A dict is accepted at construction and
    # normalized to a sorted tuple of pairs so the engine stays hashable
    # (jit static args). None / missing role = this engine's base mode.
    layer_modes: Union[Mapping[str, str],
                       Tuple[Tuple[str, str], ...], None] = None
    # Mesh-sharded dispatch (the distributed front-end): when BOTH mesh
    # and shard are set, olm GEMMs route through the shard_map wrapper
    # (kernels/online_dot/matmul_sharded) instead of the single-device
    # kernel. shard names the partitioned GEMM dimension: "m"/"n" keep
    # every output tile fully local (bit-identical per shard to the
    # single-device kernel), "k" splits the contraction and psums the
    # f32 partial accumulators (olm_error_bound still holds; the
    # reduction ORDER differs from single-device — see matmul_sharded).
    # jax.sharding.Mesh is hashable, so the engine stays a valid jit
    # static argument. Non-olm modes ignore all three.
    mesh: Optional[jax.sharding.Mesh] = None
    shard: Optional[str] = None       # None | "m" | "n" | "k"
    shard_axis: str = "model"         # mesh axis the shard maps over

    _ROLES = frozenset({"attn", "mlp", "head"})

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(
                f"unknown DotEngine mode {self.mode!r}; registered: "
                f"{', '.join(sorted(_MODES))}")
        if self.tiling not in (None, "auto"):
            raise ValueError(
                f"unknown DotEngine tiling {self.tiling!r}; expected "
                "None (static knobs / kernel defaults) or 'auto'")
        if self.shard not in (None, "m", "n", "k"):
            raise ValueError(
                f"unknown DotEngine shard {self.shard!r}; expected None "
                "or one of 'm', 'n', 'k'")
        if self.layer_modes is not None:
            pairs = tuple(sorted(dict(self.layer_modes).items()))
            if bad := {r for r, _ in pairs} - self._ROLES:
                raise ValueError(
                    f"unknown layer_modes roles {sorted(bad)}; expected "
                    f"a subset of {sorted(self._ROLES)}")
            if bad := {m for _, m in pairs if m not in _MODES}:
                raise ValueError(
                    f"layer_modes names unregistered modes {sorted(bad)}; "
                    f"registered: {', '.join(sorted(_MODES))}")
            object.__setattr__(self, "layer_modes", pairs or None)

    def for_role(self, role: str) -> "DotEngine":
        """The engine a GEMM of the given role ("attn" / "mlp" / "head")
        should run under: self unless layer_modes overrides that role,
        in which case an engine with the override as its base mode (all
        deployment/tiling knobs carried over; layer_modes cleared so the
        resolved engine is a plain single-mode engine)."""
        if role not in self._ROLES:
            raise ValueError(f"unknown GEMM role {role!r}; expected one "
                             f"of {sorted(self._ROLES)}")
        if not self.layer_modes:
            return self
        mode = dict(self.layer_modes).get(role)
        if mode is None or mode == self.mode:
            return self
        return dataclasses.replace(self, mode=mode, layer_modes=None)

    @staticmethod
    def modes() -> Tuple[str, ...]:
        """Names of all registered modes."""
        return tuple(sorted(_MODES))

    @staticmethod
    def mode_table() -> Tuple[DotMode, ...]:
        """Registered modes with their error/cost documentation (the
        source of the README mode table)."""
        return tuple(_MODES[m] for m in sorted(_MODES))

    def spec(self) -> "EngineSpec":
        """This engine as an EngineSpec: every field pinned, so
        ``resolve_engine(eng.spec()) == eng`` (round-trip contract)."""
        return EngineSpec(
            mode=self.mode, interpret=self.interpret,
            use_pallas=self.use_pallas, k_tile=self.k_tile,
            block_m=self.block_m, block_n=self.block_n, tiling=self.tiling,
            layer_modes=self.layer_modes, mesh=self.mesh, shard=self.shard,
            shard_axis=self.shard_axis)

    def dot(self, x: jax.Array, w: jax.Array) -> jax.Array:
        """x (..., K) @ w (K, N) -> (..., N), in this engine's numerics.

        Weights stay in their stored dtype until the mode decides: native
        casts to the activation compute dtype; the digit modes quantize
        from the stored precision directly (fp32 master copies are never
        pre-rounded through bf16)."""
        return _MODES[self.mode].fn(self, x, w)

    def einsum(self, spec: str, a: jax.Array, b: jax.Array) -> jax.Array:
        return jnp.einsum(spec, a, b)


class _Unset:
    """Sentinel distinguishing "leave this field to the base engine"
    from an explicit None/value in EngineSpec (e.g. k_tile=None means
    CLEAR the pin back to the kernel default; k_tile=_UNSET means
    inherit whatever the base engine had)."""
    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self):
        return "<unset>"


_UNSET = _Unset()


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """One declarative description of a numerics engine — the single
    front door that replaces the three-way construction sprawl
    (``engine_for(n, trunc=p)`` / direct ``DotEngine(...)`` kwargs /
    ``ServeEngine(dot_mode=..., dot_tiling=..., quality_tiers=...)``).

    ``resolve_engine(spec)`` turns it into a concrete DotEngine. Name
    the mode either directly (``mode="olm32t16"``) or structurally
    (``n_bits=32, trunc=16``) — never both. Every other field defaults
    to the _UNSET sentinel, meaning "inherit from the base engine"
    when resolving against one (``resolve_engine(spec, base=model.eng)``);
    an explicit None overrides the base (clears a pin). The serving-only
    fields (quality_tiers, degrade_ladder) ride the spec unchanged and
    are consumed by ServeEngine, not by resolve_engine.

    Frozen and hashable: dict-valued fields are normalized to sorted
    tuples at construction, mirroring DotEngine.layer_modes.
    """
    mode: Optional[str] = None
    n_bits: Optional[int] = None
    trunc: Optional[int] = None
    interpret: Any = _UNSET
    use_pallas: Any = _UNSET
    k_tile: Any = _UNSET
    block_m: Any = _UNSET
    block_n: Any = _UNSET
    tiling: Any = _UNSET
    layer_modes: Any = _UNSET
    # Distributed front-end (DotEngine.mesh/shard/shard_axis).
    mesh: Any = _UNSET
    shard: Any = _UNSET
    shard_axis: Any = _UNSET
    # Serving-only: per-request quality tiers {tier: mode-or-spec-dict}
    # and the degrade ladder (see serving/engine.py). None = unset.
    quality_tiers: Any = None
    degrade_ladder: Any = None

    def __post_init__(self):
        if self.mode is not None and self.n_bits is not None:
            raise ValueError(
                "EngineSpec: give mode= or n_bits= (structural), not both")
        if self.trunc is not None and self.n_bits is None:
            raise ValueError(
                "EngineSpec: trunc= requires n_bits= (structural naming)")
        if isinstance(self.layer_modes, Mapping):
            object.__setattr__(self, "layer_modes",
                               tuple(sorted(self.layer_modes.items())))
        if isinstance(self.quality_tiers, Mapping):
            object.__setattr__(self, "quality_tiers",
                               tuple(sorted(self.quality_tiers.items())))
        if isinstance(self.degrade_ladder, list):
            object.__setattr__(self, "degrade_ladder",
                               tuple(self.degrade_ladder))


# DotEngine fields an EngineSpec can override (same names on both).
_SPEC_ENGINE_FIELDS = ("interpret", "use_pallas", "k_tile", "block_m",
                       "block_n", "tiling", "layer_modes", "mesh", "shard",
                       "shard_axis")


def resolve_engine(spec: EngineSpec, base: Optional[DotEngine] = None,
                   mesh=None) -> DotEngine:
    """Resolve an EngineSpec into a concrete DotEngine.

    Field resolution order: explicit spec field > ``mesh=`` argument
    (mesh only) > ``base`` engine field > DotEngine default. The mode
    comes from ``spec.mode``, or is derived from ``spec.n_bits`` /
    ``spec.trunc`` (``olm{n}`` / ``olm{n}t{p}``) and validated against
    the registry; with neither set, the base engine's mode (or the
    DotEngine default) stands.
    """
    if base is not None and not isinstance(base, DotEngine):
        raise TypeError(f"base must be a DotEngine, got {type(base).__name__}")
    kw = ({} if base is None else
          {f.name: getattr(base, f.name) for f in dataclasses.fields(DotEngine)})
    if spec.mode is not None:
        kw["mode"] = spec.mode
    elif spec.n_bits is not None:
        name = (f"olm{spec.n_bits}t{spec.trunc}" if spec.trunc is not None
                else f"olm{spec.n_bits}")
        if name not in _MODES:
            raise ValueError(
                f"EngineSpec(n_bits={spec.n_bits}, trunc={spec.trunc}) "
                f"resolves to unregistered mode {name!r}; registered: "
                f"{', '.join(sorted(_MODES))}")
        kw["mode"] = name
    if mesh is not None:
        kw["mesh"] = mesh
    for name in _SPEC_ENGINE_FIELDS:
        v = getattr(spec, name)
        if v is not _UNSET:
            kw[name] = v
    return DotEngine(**kw)
