"""DotEngine: pluggable matmul numerics for the whole model stack.

Modes:
  native  — dot in the model's compute dtype (bf16 on TPU); baseline.
  tpmm16 / tpmm8 — the paper's truncated-precision inner products
    (kernels/tpmm): operands decomposed into digit planes, plane pairs
    beyond the significance cutoff never computed. n_bits = 16 / 8.

The engine is threaded through every dense, attention and MoE matmul, so
the paper's technique is a first-class numerics choice per model config,
not a bolted-on demo. einsum falls back to native numerics for the
attention contractions (their operands are activations on both sides;
tpmm targets the weight-bearing GEMMs, which dominate FLOPs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["DotEngine"]


@dataclasses.dataclass(frozen=True)
class DotEngine:
    mode: str = "native"          # native | tpmm16 | tpmm8
    interpret: bool = True        # Pallas interpret mode (CPU container)
    use_pallas: bool = False      # jnp oracle by default inside big models

    def dot(self, x: jax.Array, w: jax.Array) -> jax.Array:
        """x (..., K) @ w (K, N) -> (..., N). Weights (stored in the param
        dtype, fp32 master copies under training) are cast to the
        activation compute dtype at use."""
        w = w.astype(x.dtype)
        if self.mode == "native":
            return jnp.einsum("...k,kn->...n", x, w)
        n_bits = 16 if self.mode == "tpmm16" else 8
        from repro.kernels.tpmm.ops import tpmm
        lead = x.shape[:-1]
        K = x.shape[-1]
        x2 = x.reshape(-1, K)
        out = tpmm(x2, w.astype(jnp.float32), n_bits=n_bits,
                   use_pallas=self.use_pallas, interpret=self.interpret)
        return out.reshape(*lead, w.shape[-1]).astype(x.dtype)

    def einsum(self, spec: str, a: jax.Array, b: jax.Array) -> jax.Array:
        return jnp.einsum(spec, a, b)
