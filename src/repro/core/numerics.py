"""DotEngine: pluggable matmul numerics for the whole model stack.

A mode registry replaces the old string-if chain: every numerics choice
is a registered `DotMode` carrying its implementation plus the
error/cost documentation the README mode table and benchmarks surface.

Registered modes:

  native   — dot in the model's compute dtype (bf16 on TPU); baseline.
  tpmm16 / tpmm8 — the paper's truncated-precision inner products
    (kernels/tpmm): operands decomposed into digit planes, plane pairs
    beyond the significance cutoff never computed. n_bits = 16 / 8.
  olm32 / olm24 / olm16 / olm8 — the paper's own inner-product array
    (kernels/online_dot via its matmul front-end) at every
    configs/olm_array.ARRAY_PRECISIONS width: K-lane online multipliers
    feeding a digit-serial online adder tree, matmul tiles quantized to
    signed-digit grids, digit streams decoded and accumulated in f32.
    n = 8/16 decode on the exact plain-f32 path; n = 24/32 stream past
    the 24-digit f32 window and take the wide decode (int64 accumulator
    under x64, two-limb f32 otherwise — kernels/common.decode_policy).
    Every fused kernel path is bit-identical to the pure-jnp oracle and
    bounded by kernels/online_dot/matmul.olm_error_bound.

The engine is threaded through every dense, attention and MoE matmul, so
the paper's technique is a first-class numerics choice per model config,
not a bolted-on demo. einsum falls back to native numerics for the
attention contractions (their operands are activations on both sides;
the digit modes target the weight-bearing GEMMs, which dominate FLOPs).

Weight dtype: only the `native` mode casts weights to the activation
compute dtype. The digit modes quantize straight from the stored dtype —
fp32 master weights under training keep their full mantissa into the
digit/plane decomposition instead of being rounded through bf16 first.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["DotEngine", "DotMode", "register_mode"]


@dataclasses.dataclass(frozen=True)
class DotMode:
    """One registered numerics mode: implementation + trade-off docs."""
    name: str
    summary: str
    error: str     # documented accuracy vs the exact f32 matmul
    cost: str      # documented compute/area trade-off
    fn: Callable[["DotEngine", jax.Array, jax.Array], jax.Array]


_MODES: Dict[str, DotMode] = {}


def register_mode(name: str, *, summary: str, error: str, cost: str):
    """Register a DotEngine mode. The decorated function receives
    (engine, x (..., K), w (K, N)) and returns (..., N). Names are
    single-assignment: silently swapping the implementation under an
    existing mode would change every model built with it."""
    def deco(fn):
        if name in _MODES:
            raise ValueError(f"DotEngine mode {name!r} already registered")
        _MODES[name] = DotMode(name, summary, error, cost, fn)
        return fn
    return deco


@register_mode(
    "native",
    summary="einsum in the model compute dtype (bf16 on TPU)",
    error="exact at compute dtype (bf16 rounding only)",
    cost="full-precision MXU matmul; baseline")
def _native_dot(eng: "DotEngine", x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))


def _lowered_dot(eng: "DotEngine", x: jax.Array, w: jax.Array,
                 matmul_fn, n_bits: int) -> jax.Array:
    """Shared digit-mode lowering: flatten the lead axes onto a 2-D tile,
    hand the weights to the kernel front-end in their stored precision
    (f32 — never pre-rounded through the activation dtype), and restore
    the activation shape/dtype on the way out."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    out = matmul_fn(x.reshape(-1, K), w.astype(jnp.float32), n_bits=n_bits,
                    use_pallas=eng.use_pallas, interpret=eng.interpret)
    return out.reshape(*lead, w.shape[-1]).astype(x.dtype)


def _tpmm_dot(eng: "DotEngine", x: jax.Array, w: jax.Array,
              n_bits: int) -> jax.Array:
    from repro.kernels.tpmm.ops import tpmm
    return _lowered_dot(eng, x, w, tpmm, n_bits)


@register_mode(
    "tpmm16",
    summary="truncated digit-plane matmul, 16-bit significance",
    error="~6e-4 relative (n-bit plane truncation, tested)",
    cost="10/16 plane-pair MXU matmuls (37.5% MXU ops saved)")
def _tpmm16(eng, x, w):
    return _tpmm_dot(eng, x, w, 16)


@register_mode(
    "tpmm8",
    summary="truncated digit-plane matmul, 8-bit significance",
    error="~8e-2 relative (n-bit plane truncation, tested)",
    cost="3/4 plane-pair MXU matmuls (25% MXU ops saved)")
def _tpmm8(eng, x, w):
    return _tpmm_dot(eng, x, w, 8)


def _olm_dot(eng: "DotEngine", x: jax.Array, w: jax.Array,
             n_bits: int) -> jax.Array:
    import functools
    import math

    from repro.kernels.online_dot.matmul import olm_matmul
    # Grid-kernel tuning knobs ride on the engine (None = the kernel
    # defaults): k_tile is the array width per K chunk, block_m/block_n
    # the output tile the Pallas grid reuses operand digit grids across.
    tiling = {k: v for k, v in (("k_tile", eng.k_tile),
                                ("block_m", eng.block_m),
                                ("block_n", eng.block_n)) if v is not None}
    if eng.tiling == "auto" and eng.use_pallas is not False:
        # Shape-aware autotuned tiling per GEMM (shapes are static at
        # trace time, so the lookup runs on the host during tracing).
        # Explicitly pinned engine knobs win over the autotuner. With
        # use_pallas=False the engine is certain to take the broadcast
        # oracle, which ignores block shapes (and auto's k_tile is the
        # pinned default anyway) — skip the lookup rather than pretend
        # it does something.
        from repro.kernels.online_dot.tuning import get_tiling
        auto = get_tiling(math.prod(x.shape[:-1]), w.shape[-1],
                          x.shape[-1], n_bits)
        tiling = {**auto, **tiling}
    fn = functools.partial(olm_matmul, **tiling) if tiling else olm_matmul
    return _lowered_dot(eng, x, w, fn, n_bits)


@register_mode(
    "olm16",
    summary="fused online inner-product array, 16-digit operands",
    error="<= k_tile * 3.1 ulp @ 2^-16 per K-tile (olm_error_bound)",
    cost="Eq.8-truncated digit-serial array; grid-tiled operand reuse "
         "(digit-grid traffic / min(block_m, block_n))")
def _olm16(eng, x, w):
    return _olm_dot(eng, x, w, 16)


@register_mode(
    "olm8",
    summary="fused online inner-product array, 8-digit operands",
    error="<= k_tile * 3.1 ulp @ 2^-8 per K-tile (olm_error_bound)",
    cost="Eq.8-truncated digit-serial array; grid-tiled operand reuse "
         "(digit-grid traffic / min(block_m, block_n))")
def _olm8(eng, x, w):
    return _olm_dot(eng, x, w, 8)


@register_mode(
    "olm24",
    summary="fused online inner-product array, 24-digit operands "
            "(wide two-limb/int64 stream decode)",
    error="<= k_tile * (3.1 @ 2^-24 + (T+1) @ 2^-26) per K-tile "
          "(olm_error_bound wide term)",
    cost="Eq.8-truncated digit-serial array at 24 digits; same grid-"
         "tiled reuse, 1.5x the olm16 digit traffic on the host path")
def _olm24(eng, x, w):
    return _olm_dot(eng, x, w, 24)


@register_mode(
    "olm32",
    summary="fused online inner-product array, 32-digit operands "
            "(wide two-limb/int64 stream decode; oracle path x64-scoped)",
    error="<= k_tile * (3.1 @ 2^-32 + (T+1) @ 2^-26) per K-tile "
          "(olm_error_bound wide term)",
    cost="Eq.8-truncated digit-serial array at 32 digits; same grid-"
         "tiled reuse, 2x the olm16 digit traffic on the host path")
def _olm32(eng, x, w):
    return _olm_dot(eng, x, w, 32)


@dataclasses.dataclass(frozen=True)
class DotEngine:
    mode: str = "native"          # any registered mode, see DotEngine.modes()
    interpret: bool = True        # Pallas interpret mode (CPU container)
    use_pallas: bool = False      # jnp oracle by default inside big models
    # olm grid-kernel tuning (None = kernel defaults; ignored by other
    # modes): K lanes per adder tree, and the (block_m, block_n) output
    # tile whose BlockSpecs set the digit-grid reuse factor.
    k_tile: Optional[int] = None
    block_m: Optional[int] = None
    block_n: Optional[int] = None
    # tiling="auto" resolves (block_m, block_n) per GEMM shape through
    # the kernels/online_dot/tuning autotuner (measured-or-heuristic,
    # persistent cache) instead of one static default; explicitly set
    # knobs above still win. Numerics are unchanged: block shapes are
    # bit-invariant, and the tuner pins k_tile (the one knob that IS a
    # numerics parameter) to the kernel default — only an explicit
    # k_tile= here changes it.
    tiling: Optional[str] = None

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(
                f"unknown DotEngine mode {self.mode!r}; registered: "
                f"{', '.join(sorted(_MODES))}")
        if self.tiling not in (None, "auto"):
            raise ValueError(
                f"unknown DotEngine tiling {self.tiling!r}; expected "
                "None (static knobs / kernel defaults) or 'auto'")

    @staticmethod
    def modes() -> Tuple[str, ...]:
        """Names of all registered modes."""
        return tuple(sorted(_MODES))

    @staticmethod
    def mode_table() -> Tuple[DotMode, ...]:
        """Registered modes with their error/cost documentation (the
        source of the README mode table)."""
        return tuple(_MODES[m] for m in sorted(_MODES))

    def dot(self, x: jax.Array, w: jax.Array) -> jax.Array:
        """x (..., K) @ w (K, N) -> (..., N), in this engine's numerics.

        Weights stay in their stored dtype until the mode decides: native
        casts to the activation compute dtype; the digit modes quantize
        from the stored precision directly (fp32 master copies are never
        pre-rounded through bf16)."""
        return _MODES[self.mode].fn(self, x, w)

    def einsum(self, spec: str, a: jax.Array, b: jax.Array) -> jax.Array:
        return jnp.einsum(spec, a, b)
