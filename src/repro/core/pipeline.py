"""Cycle-accurate simulator of the unrolled pipelined online multiplier.

The paper unrolls the n + delta iterations into n + delta + 1 pipeline
stages (the +1 is the output register). A stream of k operand pairs enters
one pair per cycle; pair i occupies stage (c - i) at cycle c. Total cycles
to drain: (n + delta + 1) + (k - 1)  — paper Table III.

Each stage is one step of the online recurrence, so the functional result
of the pipelined array is identical to running each pair through the
non-pipelined reference (asserted in tests). What the simulator adds is the
*per-cycle* view: live bit-slices per stage (the Fig. 7 schedule applied to
whichever pair occupies the stage), register switching activity, and
pipeline utilization — the quantities behind the paper's area/power story.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from .online_mul import OnlineMulState, OnlineMulTrace, working_precision
from .precision import OnlinePrecision

__all__ = ["PipelineRun", "run_pipeline", "stage_slice_schedule"]


def stage_slice_schedule(cfg: OnlinePrecision) -> List[int]:
    """Live fractional slices built in each unrolled stage (stage s runs
    step j = s - delta). The output stage (last) carries no datapath."""
    return [working_precision(cfg, s - cfg.delta) for s in range(cfg.steps)] + [0]


@dataclasses.dataclass
class PipelineRun:
    traces: List[OnlineMulTrace]       # per-pair results (== reference)
    cycles: int                        # total cycles to drain the stream
    active_slices_per_cycle: List[int]  # sum of live slices across stages
    flips_total: int                   # register switching activity
    stage_slices: List[int]            # structural slices per stage

    @property
    def peak_active(self) -> int:
        return max(self.active_slices_per_cycle) if self.active_slices_per_cycle else 0

    @property
    def utilization(self) -> float:
        """Mean occupied-stage fraction over the run."""
        if not self.active_slices_per_cycle:
            return 0.0
        total_struct = sum(self.stage_slices)
        return sum(self.active_slices_per_cycle) / (len(self.active_slices_per_cycle) * max(total_struct, 1))


def run_pipeline(
    pairs: Sequence[Tuple[Sequence[int], Sequence[int]]],
    cfg: OnlinePrecision,
) -> PipelineRun:
    """Stream k operand pairs through the unrolled pipeline.

    Args:
      pairs: sequence of (x_digits, y_digits), each n digits.
      cfg: multiplier precision configuration.

    Returns PipelineRun with per-pair traces and cycle-level activity.
    """
    k = len(pairs)
    n_stages = cfg.steps  # compute stages; +1 output register stage
    states: List[OnlineMulState | None] = [None] * k
    activity: List[int] = []
    flips_before = 0
    total_cycles = cfg.pipeline_latency + (k - 1) if k else 0

    for c in range(total_cycles):
        live = 0
        # pair i is in compute stage s = c - i for 0 <= s < n_stages
        lo = max(0, c - n_stages + 1)
        hi = min(k - 1, c)
        for i in range(lo, hi + 1):
            s = c - i
            if s >= n_stages:
                continue  # output register stage
            if states[i] is None:
                states[i] = OnlineMulState(cfg)
            st = states[i]
            assert st is not None and st.j == s - cfg.delta
            st.step(pairs[i][0], pairs[i][1])
            live += st.active[-1]
        activity.append(live)

    traces = []
    flips = 0
    for i, st in enumerate(states):
        assert st is not None and st.done, f"pair {i} did not drain"
        flips += st.flips
        traces.append(
            OnlineMulTrace(
                z_digits=st.z_digits,
                z_int=st.Z,
                residual_bound=st.wmax,
                active_per_step=st.active,
                selm_inputs=st.selm_inputs,
                flips=st.flips,
            )
        )
    return PipelineRun(
        traces=traces,
        cycles=total_cycles,
        active_slices_per_cycle=activity,
        flips_total=flips,
        stage_slices=stage_slice_schedule(cfg),
    )
