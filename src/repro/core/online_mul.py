"""Bit-exact reference of the radix-2 online multiplier (full & truncated p).

Implements the recurrence of the paper (Eqs. 2-7) with exact integer
arithmetic, plus the paper's working-precision truncation (Eq. 8 / Fig. 7).

Datapath model
--------------
All quantities are integers scaled by 2^F with F = n + delta (the deepest
bit position any append can reach in the full design).

The *working precision* at step j is a schedule T(j) (Fig. 7):

    ramp    : T = j + 2*delta + 1      (digits accumulated so far + shift)
    plateau : T = p = ceil((2n+delta+t)/3)           (paper Eq. 8)
    tail    : T = t + (n-1-j) + tail_guard           ("error profile" decay)

At step j the appended term, the residual and the operand registers are
truncated (two's-complement floor) below 2^-T(j). Arriving digits always
drive the SELECTOR muxes (their +-register contribution lands at the top of
the scaled residual); only their *storage* into register slices is gated.
The full (non-truncated) design uses T(j) = min(j + 2*delta + 1, n + delta).

Validated properties (tests/test_online_mul.py):
  * full design:      |z - x*y| <= 0.5 ulp @ 2^-n   (exhaustive n=8)
  * truncated (Eq.8): |z - x*y| <  1.1 ulp @ 2^-n   (exhaustive n=8)
  * tail gating with tail_guard >= 1 is bit-identical to plateau-only.

This module is the gold oracle for kernels/online_mul (Pallas) and its
vectorized jnp reference.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from .precision import OnlinePrecision

__all__ = [
    "OnlineMulState",
    "OnlineMulTrace",
    "online_multiply",
    "selm",
    "working_precision",
]


def selm(v_hat_quarters: int) -> int:
    """Digit selection (paper Eq. 7) on the t=2-bit truncated estimate,
    expressed in units of 1/4.

      v_hat >= 1/2          -> +1
      -1/2 <= v_hat <= 1/4  ->  0
      v_hat <= -3/4         -> -1

    v_hat is a multiple of 1/4, so the three cases are exhaustive.
    """
    if v_hat_quarters >= 2:
        return 1
    if v_hat_quarters >= -2:
        return 0
    return -1


def working_precision(cfg: OnlinePrecision, j: int) -> int:
    """T(j): live fractional bit-slices of the datapath at step j
    (j in [-delta, n-1]). This is the Fig. 7 activity schedule.

    The non-truncated baseline keeps the natural fill ramp (registers are
    empty until digits arrive) but no plateau cap and no tail decay; the
    proposed design adds the Eq. 8 plateau and the error-profile tail.
    NOTE: paper Fig. 5's caption suggests the conventional design keeps all
    n slices active in every stage; we use the *conservative* ramped
    baseline, which understates our savings relative to Table I.
    """
    n, d, t = cfg.n, cfg.delta, cfg.t
    full = n + d
    ramp = j + 2 * d + 1
    if not cfg.truncated:
        return max(min(ramp, full), 1)
    T = min(ramp, cfg.p)
    if cfg.tail_gating and j >= 0:
        tail = t + (n - 1 - j) + cfg.tail_guard
        T = min(T, max(tail, t + 1))
    return max(T, 1)


def _floor_at(value: int, keep_frac_bits: int, scale_bits: int) -> int:
    """Truncate (floor) `value` scaled by 2^scale_bits below 2^-keep_frac_bits."""
    drop = scale_bits - keep_frac_bits
    if drop <= 0:
        return value
    return (value >> drop) << drop


class OnlineMulState:
    """One multiplier's architectural state, advanced one step per cycle.

    Used directly by `online_multiply` and by the unrolled-pipeline
    simulator (core/pipeline.py), which keeps one in-flight state per
    operand pair and advances each through the stage it currently occupies.
    """

    __slots__ = ("cfg", "F", "X", "Y", "W", "Z", "j", "z_digits",
                 "selm_inputs", "active", "wmax", "flips")

    def __init__(self, cfg: OnlinePrecision):
        self.cfg = cfg
        self.F = cfg.n + cfg.delta
        self.X = 0
        self.Y = 0
        self.W = 0
        self.Z = 0
        self.j = -cfg.delta
        self.z_digits: List[int] = []
        self.selm_inputs: List[int] = []
        self.active: List[int] = []
        self.wmax = 0.0
        self.flips = 0  # register bit flips (switching-activity proxy)

    @property
    def done(self) -> bool:
        return self.j >= self.cfg.n

    def step(self, x_digits: Sequence[int], y_digits: Sequence[int]) -> int | None:
        """Advance one iteration; returns the output digit (None during
        initialization). x_digits/y_digits are the full operand digit
        vectors; the state fetches the digit arriving this cycle."""
        cfg, F = self.cfg, self.F
        d, t, n = cfg.delta, cfg.t, cfg.n
        j = self.j
        T = working_precision(cfg, j)
        q = j + 1 + d  # arriving digit position
        xd_new = x_digits[q - 1] if 1 <= q <= n else 0
        yd_new = y_digits[q - 1] if 1 <= q <= n else 0
        # Register (CA-REG) slice gating: a slice beyond the live datapath
        # width is not built, so the arriving digit's own bit is never
        # *stored* (and cannot generate floor-boundary borrows); the digit
        # still drives the SELECTOR muxes below.
        store = 1 <= q <= T
        # v[j] = 2 w[j] + (x[j]*y_{j+1+d} + y[j+1]*x_{j+1+d}) * 2^-d ; the
        # arriving digits are SELECTOR mux *controls* and always apply.
        Y_full = self.Y + yd_new * (1 << (F - q)) if (yd_new and store) else self.Y
        term = self.X * yd_new + Y_full * xd_new  # scaled 2^F
        # 2^-delta scaling; arithmetic shift right == two's-complement
        # floor; then truncation to the live datapath width T(j):
        append = _floor_at(term >> d, T, F)
        X_full = self.X + xd_new * (1 << (F - q)) if (xd_new and store) else self.X
        X_new = _floor_at(X_full, T, F)
        Y_new = _floor_at(Y_full, T, F)
        V = 2 * self.W + append
        out: int | None = None
        if j >= 0:
            vq = V >> (F - t)  # selection estimate in quarters (floor)
            zj = selm(vq)
            self.selm_inputs.append(vq)
            self.z_digits.append(zj)
            self.Z = 2 * self.Z + zj  # builds sum z_i 2^(n-i)
            W_new = V - zj * (1 << F)
            out = zj
        else:
            W_new = V
        W_new = _floor_at(W_new, T, F)
        self.flips += (
            bin((X_new ^ self.X) & ((1 << (F + 4)) - 1)).count("1")
            + bin((Y_new ^ self.Y) & ((1 << (F + 4)) - 1)).count("1")
            + bin((W_new ^ self.W) & ((1 << (F + 4)) - 1)).count("1")
        )
        self.X, self.Y, self.W = X_new, Y_new, W_new
        self.active.append(T)
        self.wmax = max(self.wmax, abs(W_new) / float(1 << F))
        self.j += 1
        return out


@dataclasses.dataclass
class OnlineMulTrace:
    """Full execution trace of one online multiplication."""

    z_digits: List[int]
    z_int: int                      # product digits as integer scaled 2^n
    residual_bound: float           # max |w[j]| observed
    active_per_step: List[int]      # live fractional slices per step (Fig. 7)
    selm_inputs: List[int]          # v-hat (quarters) per digit-producing step
    flips: int                      # register bit flips across the run

    @property
    def n(self) -> int:
        return len(self.z_digits)

    @property
    def z_value(self) -> float:
        return self.z_int / float(1 << self.n)


def online_multiply(
    x_digits: Sequence[int],
    y_digits: Sequence[int],
    cfg: OnlinePrecision | None = None,
) -> OnlineMulTrace:
    """Multiply two n-digit SD fractions with the online algorithm.

    Args:
      x_digits, y_digits: n signed digits each (MSD first), value in (-1, 1).
      cfg: precision configuration; defaults to truncated p per Eq. 8 with
        the Fig. 7 tail schedule.

    Returns an OnlineMulTrace with output digits z_1..z_n.
    """
    n = len(x_digits)
    if len(y_digits) != n:
        raise ValueError("operands must have equal digit counts")
    if cfg is None:
        cfg = OnlinePrecision(n=n)
    if cfg.n != n:
        raise ValueError(f"cfg.n={cfg.n} != len(digits)={n}")
    st = OnlineMulState(cfg)
    while not st.done:
        st.step(x_digits, y_digits)
    return OnlineMulTrace(
        z_digits=st.z_digits,
        z_int=st.Z,
        residual_bound=st.wmax,
        active_per_step=st.active,
        selm_inputs=st.selm_inputs,
        flips=st.flips,
    )
