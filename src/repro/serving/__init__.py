from .degrade import DegradeLadder
from .engine import Request, ServeEngine
from .faults import (FaultConfig, FaultInjector, TransientPrefillError,
                     build_fault_plan)
from .replay import ReplayConfig, build_workload, run_replay, step_report
from .report import ServeReport
