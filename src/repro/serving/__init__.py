from .engine import Request, ServeEngine
from .replay import ReplayConfig, build_workload, run_replay, step_report
