"""Deterministic traffic-replay harness for the serving engine.

A seeded arrival process generates a fixed workload (arrival step, prompt,
max_new_tokens per request); `run_replay` drives a ServeEngine one
scheduler step at a time, submitting requests when their arrival step
comes up, and reports latency percentiles in **scheduler steps** — the
engine's virtual clock — rather than wall time. Step metrics are a pure
function of the workload and the scheduler logic (requests use
eos_id=None, so termination never depends on sampled token values),
which makes them stable across hosts and JAX versions: the replay bench
commits them to `results/baseline/` and `tools/check_bench.py` diffs
every run against that seed. Wall-clock figures are reported alongside
for humans but never gated in CI (`REPRO_REPLAY_WALLCLOCK=1` turns on
an opt-in tolerance gate — see tools/check_bench.py).

A replay optionally carries a :class:`~repro.serving.faults.FaultInjector`
(``run_replay(..., faults=...)``): faults are applied at each step
boundary *before* the engine steps, so a given (workload seed, fault
plan) pair resolves identically every run — the `serve_faults` bench
baselines that resolution.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .engine import Request, ServeEngine
from .faults import FaultInjector
from .report import ServeReport

__all__ = ["ReplayConfig", "build_workload", "run_replay", "step_report"]


@dataclasses.dataclass(frozen=True)
class ReplayConfig:
    seed: int = 0
    n_requests: int = 24
    mean_interarrival_steps: float = 2.0
    prompt_len_range: Tuple[int, int] = (4, 24)   # inclusive
    max_new_range: Tuple[int, int] = (4, 10)      # inclusive
    vocab: int = 512
    # Deadlines: every deadline_every-th request (1-indexed; 0 = none)
    # gets a deadline of deadline_steps scheduler steps. Defaults keep
    # pre-existing seeded workloads byte-identical.
    deadline_every: int = 0
    deadline_steps: int = 0
    # Priorities: cycle request priority over 0..priority_levels-1
    # (1 = all equal, the default) to exercise victim selection.
    priority_levels: int = 1


def build_workload(cfg: ReplayConfig) -> List[Dict[str, object]]:
    """Seeded arrival schedule: [{arrival_step, prompt, max_new}, ...],
    sorted by arrival. numpy Generator bit streams are stable across
    numpy versions, so the same seed is the same workload everywhere."""
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.geometric(1.0 / max(cfg.mean_interarrival_steps, 1.0),
                         cfg.n_requests) - 1
    arrivals = np.cumsum(gaps)
    lo, hi = cfg.prompt_len_range
    lens = rng.integers(lo, hi + 1, cfg.n_requests)
    nlo, nhi = cfg.max_new_range
    max_new = rng.integers(nlo, nhi + 1, cfg.n_requests)
    out: List[Dict[str, object]] = []
    for i in range(cfg.n_requests):
        w: Dict[str, object] = {
            "arrival_step": int(arrivals[i]),
            "prompt": rng.integers(1, cfg.vocab, int(lens[i])).astype(np.int32),
            "max_new": int(max_new[i]),
        }
        if cfg.deadline_every and (i + 1) % cfg.deadline_every == 0:
            w["deadline_steps"] = int(cfg.deadline_steps)
        if cfg.priority_levels > 1:
            w["priority"] = int(i % cfg.priority_levels)
        out.append(w)
    return out


def run_replay(engine: ServeEngine, workload: List[Dict[str, object]],
               *, max_steps: int = 100_000,
               faults: Optional[FaultInjector] = None,
               ) -> Tuple[List[Request], ServeReport]:
    """Drive the engine through the workload; returns (done, step_report).

    The report is a :class:`~repro.serving.report.ServeReport` — virtual-
    clock step metrics plus the unified counter surface (finish_reasons /
    preempts / retries / degrades, legacy ``n_*`` keys readable as
    aliases) and ``wall_s``. Deliberately NO wall-clock latency fields
    beyond wall_s: the chaos bench diffs every non-wall_s entry exactly
    across runs, so everything here must be a pure function of the
    (workload, fault plan, engine config) triple.

    Requests are submitted when the engine's step counter reaches their
    arrival step, so queueing pressure replays identically every run.
    With a FaultInjector, fault events fire at the step boundary right
    after that step's submissions — deterministic in the virtual clock.
    """
    pending = sorted(workload, key=lambda w: w["arrival_step"])
    reqs = [Request(rid=i, prompt=w["prompt"], max_new_tokens=w["max_new"],
                    eos_id=None,
                    deadline_steps=w.get("deadline_steps"),
                    priority=w.get("priority", 0))
            for i, w in enumerate(pending)]
    if faults is not None:
        faults.attach(engine)
    done: List[Request] = []
    i = 0
    t0 = time.monotonic()
    for _ in range(max_steps):
        while i < len(pending) and \
                pending[i]["arrival_step"] <= engine.step_count:
            engine.submit(reqs[i])
            i += 1
        if faults is not None:
            faults.apply(engine, engine.step_count)
        if i == len(pending) and not engine.queue and not engine.active \
                and engine.pending_chunk is None:
            # fully drained: deferred fault events can never fire now
            break
        engine.step(done)
    if faults is not None:
        faults.finalize(engine)
    engine._drain_shed(done)
    wall_s = time.monotonic() - t0
    report = step_report(done)
    report["wall_s"] = wall_s
    return done, report


def step_report(done: List[Request]) -> ServeReport:
    """Latency percentiles in scheduler steps (deterministic; see module
    docstring). p50/p99 use numpy's default linear interpolation.

    Returns a ServeReport: per-reason counts live under the one
    `finish_reasons` mapping and the robustness counters under their
    canonical names (preempts/retries/degrades); the historical
    `n_cache_full` / `n_preempts` / ... spellings stay readable as
    ServeReport aliases."""
    if not done:
        return ServeReport()

    def pcts(vals):
        return (round(float(np.percentile(vals, 50)), 4),
                round(float(np.percentile(vals, 99)), 4))

    ttft = [r.s_first - r.s_submit for r in done if r.s_first is not None]
    e2e = [r.s_done - r.s_submit for r in done if r.s_done is not None]
    ttft_p50, ttft_p99 = pcts(ttft) if ttft else (float("nan"),) * 2
    e2e_p50, e2e_p99 = pcts(e2e) if e2e else (float("nan"),) * 2
    new_tokens = sum(len(r.output) for r in done)
    steps = max(max((r.s_done for r in done if r.s_done is not None),
                    default=1), 1)
    return ServeReport({
        "n": len(done),
        "finish_reasons": ServeReport.finish_reasons(done),
        "ttft_steps_p50": ttft_p50,
        "ttft_steps_p99": ttft_p99,
        "e2e_steps_p50": e2e_p50,
        "e2e_steps_p99": e2e_p99,
        "new_tokens": new_tokens,
        "steps_total": steps,
        "tokens_per_step": round(new_tokens / steps, 4),
        "preempts": sum(r.n_preempts for r in done),
        "retries": sum(r.n_retries for r in done),
        "degrades": sum(r.degrade_rung > 0 for r in done),
    })
