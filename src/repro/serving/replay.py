"""Deterministic traffic-replay harness for the serving engine.

A seeded arrival process generates a fixed workload (arrival step, prompt,
max_new_tokens per request); `run_replay` drives a ServeEngine one
scheduler step at a time, submitting requests when their arrival step
comes up, and reports latency percentiles in **scheduler steps** — the
engine's virtual clock — rather than wall time. Step metrics are a pure
function of the workload and the scheduler logic (requests use
eos_id=None, so termination never depends on sampled token values),
which makes them stable across hosts and JAX versions: the replay bench
commits them to `results/baseline/` and `tools/check_bench.py` diffs
every run against that seed. Wall-clock figures are reported alongside
for humans but never gated.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .engine import Request, ServeEngine

__all__ = ["ReplayConfig", "build_workload", "run_replay", "step_report"]


@dataclasses.dataclass(frozen=True)
class ReplayConfig:
    seed: int = 0
    n_requests: int = 24
    mean_interarrival_steps: float = 2.0
    prompt_len_range: Tuple[int, int] = (4, 24)   # inclusive
    max_new_range: Tuple[int, int] = (4, 10)      # inclusive
    vocab: int = 512


def build_workload(cfg: ReplayConfig) -> List[Dict[str, object]]:
    """Seeded arrival schedule: [{arrival_step, prompt, max_new}, ...],
    sorted by arrival. numpy Generator bit streams are stable across
    numpy versions, so the same seed is the same workload everywhere."""
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.geometric(1.0 / max(cfg.mean_interarrival_steps, 1.0),
                         cfg.n_requests) - 1
    arrivals = np.cumsum(gaps)
    lo, hi = cfg.prompt_len_range
    lens = rng.integers(lo, hi + 1, cfg.n_requests)
    nlo, nhi = cfg.max_new_range
    max_new = rng.integers(nlo, nhi + 1, cfg.n_requests)
    return [
        {
            "arrival_step": int(arrivals[i]),
            "prompt": rng.integers(1, cfg.vocab, int(lens[i])).astype(np.int32),
            "max_new": int(max_new[i]),
        }
        for i in range(cfg.n_requests)
    ]


def run_replay(engine: ServeEngine, workload: List[Dict[str, object]],
               *, max_steps: int = 100_000
               ) -> Tuple[List[Request], Dict[str, float]]:
    """Drive the engine through the workload; returns (done, step_report).

    Requests are submitted when the engine's step counter reaches their
    arrival step, so queueing pressure replays identically every run.
    """
    pending = sorted(workload, key=lambda w: w["arrival_step"])
    reqs = [Request(rid=i, prompt=w["prompt"], max_new_tokens=w["max_new"],
                    eos_id=None)
            for i, w in enumerate(pending)]
    done: List[Request] = []
    i = 0
    t0 = time.monotonic()
    for _ in range(max_steps):
        while i < len(pending) and \
                pending[i]["arrival_step"] <= engine.step_count:
            engine.submit(reqs[i])
            i += 1
        if i == len(pending) and not engine.queue and not engine.active \
                and engine.pending_chunk is None:
            break
        engine.step(done)
    wall_s = time.monotonic() - t0
    report = step_report(done)
    report["wall_s"] = wall_s
    return done, report


def step_report(done: List[Request]) -> Dict[str, float]:
    """Latency percentiles in scheduler steps (deterministic; see module
    docstring). p50/p99 use numpy's default linear interpolation."""
    if not done:
        return {}

    def pcts(vals):
        return (round(float(np.percentile(vals, 50)), 4),
                round(float(np.percentile(vals, 99)), 4))

    ttft = [r.s_first - r.s_submit for r in done if r.s_first is not None]
    e2e = [r.s_done - r.s_submit for r in done if r.s_done is not None]
    ttft_p50, ttft_p99 = pcts(ttft) if ttft else (float("nan"),) * 2
    e2e_p50, e2e_p99 = pcts(e2e) if e2e else (float("nan"),) * 2
    new_tokens = sum(len(r.output) for r in done)
    steps = max(max((r.s_done for r in done if r.s_done is not None),
                    default=1), 1)
    return {
        "n": len(done),
        "ttft_steps_p50": ttft_p50,
        "ttft_steps_p99": ttft_p99,
        "e2e_steps_p50": e2e_p50,
        "e2e_steps_p99": e2e_p99,
        "new_tokens": new_tokens,
        "steps_total": steps,
        "tokens_per_step": round(new_tokens / steps, 4),
        "n_cache_full": sum(r.finish_reason == "cache_full" for r in done),
    }
