"""Continuous-batching serving engine.

A fixed pool of `slots` decode lanes shares one jitted decode step; a
request queue feeds empty lanes. Prefill runs per-request (padded to the
pool's prompt bucket) and writes that lane's slice of the batched KV
cache; decode steps advance every active lane together. Finished lanes
(EOS or max_tokens) are recycled immediately — the decode batch never
drains waiting for stragglers, which is the serving-side analogue of the
paper's pipeline never idling between vector elements (Table III).

This is deliberately the simple slot-based continuous batching (vLLM-style
paged KV is out of scope); the KV cache is a contiguous (B, T, H, D) ring
per layer managed by the model's cache pytree.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 512, greedy: bool = True,
                 dot_mode: Optional[str] = None,
                 dot_tiling: Union[str, Dict[str, object], None] = None):
        # Per-deployment numerics override: serve the same checkpoint under
        # any registered DotEngine mode — every configs/olm_array
        # ARRAY_PRECISIONS width ("olm8" .. "olm32") routes decode GEMMs
        # through the fused inner-product array; the n = 24/32 modes
        # transparently use the wide (int64/two-limb) stream decode —
        # without touching the model config or the engine's
        # interpret/use_pallas deployment knobs.
        # dot_tiling tunes the olm grid kernel per deployment:
        # the string "auto" (or {"tiling": "auto"}) turns on the
        # shape-aware autotuner so prefill GEMMs and decode GEMVs each
        # get their own (block_m, block_n) output tile — k_tile stays
        # at the numerics default, so auto never changes outputs;
        # explicit k_tile / block_m / block_n pins override it (e.g.
        # widen block_n for the fat decode GEMVs). Params are unchanged
        # — the digit modes quantize at use from the stored dtype.
        if isinstance(dot_tiling, str):
            if dot_tiling != "auto":
                raise ValueError(
                    f"unknown dot_tiling {dot_tiling!r}: the only string "
                    "form is 'auto' (or pass a dict of knobs)")
            dot_tiling = {"tiling": "auto"}
        override = dict(dot_tiling or {})
        if bad := set(override) - {"k_tile", "block_m", "block_n", "tiling"}:
            raise ValueError(f"unknown dot_tiling knobs: {sorted(bad)}")
        if override.get("tiling") == "auto":
            # Asking for the autotuner must actually engage it: clear
            # the block knobs the model's engine had pinned (explicit
            # knobs win over auto inside the engine, so stale static
            # pins would silently turn "auto" into a no-op). Blocks are
            # pure perf, so clearing them is safe; a pinned k_tile is a
            # numerics choice (quantization slice width / tree depth)
            # and survives — auto would supply the same default anyway
            # unless the model builder pinned it deliberately. Knobs
            # passed in this same dot_tiling dict survive too.
            for knob in ("block_m", "block_n"):
                override.setdefault(knob, None)
        if dot_mode is not None and dot_mode != model.eng.mode:
            override["mode"] = dot_mode
        if override:
            model = Model(model.cfg,
                          dataclasses.replace(model.eng, **override))
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.cache = model.init_cache(slots, max_len)
        self.active: Dict[int, Request] = {}       # slot -> request
        self.pos = np.zeros((slots,), np.int32)
        self.last_tok = np.zeros((slots,), np.int32)
        self.queue: Deque[Request] = deque()
        self.memory = None                          # encdec/vlm stub memory

        self._decode = jax.jit(
            lambda p, t, ps, c, m: model.decode_step(p, t, ps, c, m))

    # ------------- client API -------------
    def submit(self, req: Request):
        req.t_submit = time.monotonic()
        self.queue.append(req)

    def run(self, *, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self._fill_slots()
            if not self.active:
                break
            self._decode_step(done)
            steps += 1
        return done

    # ------------- internals -------------
    def _fill_slots(self):
        for slot in range(self.slots):
            if slot in self.active or not self.queue:
                continue
            req = self.queue.popleft()
            self._prefill_into(slot, req)
            self.active[slot] = req

    def _prefill_into(self, slot: int, req: Request):
        """Single-request prefill into one lane: run the prompt through a
        fresh single-row cache, then scatter it into the pool."""
        P = len(req.prompt)
        row_cache = self.model.init_cache(1, self.max_len)
        batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        logits, row_cache, _mem = self.model.prefill(
            self.params, batch, row_cache)
        tok = int(jnp.argmax(logits[0]))
        req.output.append(tok)
        req.t_first = time.monotonic()
        self.last_tok[slot] = tok
        self.pos[slot] = P

        def put_row(pool, row):
            # "len" scalars: decode masks by per-lane pos, keep the max
            if pool.ndim == 0:
                return jnp.maximum(pool, row)
            # the batch axis is the unique axis where shapes differ
            # (slots vs 1); scatter the row into that lane
            diff = [i for i in range(pool.ndim)
                    if pool.shape[i] != row.shape[i]]
            ax = diff[0] if diff else (1 if pool.ndim > 1 else 0)
            idx = [0] * pool.ndim
            idx[ax] = slot
            return jax.lax.dynamic_update_slice(
                pool, row.astype(pool.dtype), tuple(idx))
        self.cache = jax.tree.map(put_row, self.cache, row_cache)

    def _decode_step(self, done: List[Request]):
        toks = jnp.asarray(self.last_tok)
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(
            self.params, toks, pos, self.cache, self.memory)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for slot, req in list(self.active.items()):
            t = int(nxt[slot])
            req.output.append(t)
            self.pos[slot] += 1
            self.last_tok[slot] = t
            finished = (len(req.output) >= req.max_new_tokens or
                        (req.eos_id is not None and t == req.eos_id) or
                        int(self.pos[slot]) >= self.max_len - 1)
            if finished:
                req.t_done = time.monotonic()
                done.append(req)
                del self.active[slot]

    # ------------- metrics -------------
    @staticmethod
    def latency_report(done: List[Request]) -> Dict[str, float]:
        if not done:
            return {}
        ttft = [r.t_first - r.t_submit for r in done if r.t_first]
        e2e = [r.t_done - r.t_submit for r in done if r.t_done]
        return {
            "n": len(done),
            "ttft_mean_s": float(np.mean(ttft)) if ttft else float("nan"),
            "e2e_mean_s": float(np.mean(e2e)) if e2e else float("nan"),
        }
