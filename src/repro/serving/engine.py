"""Continuous-batching serving engine with a paged KV cache and
disaggregated prefill/decode dispatch.

A fixed pool of `slots` decode lanes shares one jitted decode step; a
request queue feeds empty lanes. The two phases are dispatched through
separately-compiled entry points so the PR-4 shape-aware autotuner
(`dot_tiling="auto"`) buckets them independently:

  * **Prefill** is GEMM-shaped: waiting requests are batched together,
    their prompts right-padded to a shared pow2 length bucket and the
    batch row count padded to a pow2 bucket, so `model.prefill` compiles
    once per (batch, length) bucket instead of once per prompt length.
    Per-lane `last_index` picks each prompt's real final position out of
    the padded rows. A `prefill_chunk` knob splits long prompts into
    fixed-size chunks interleaved with decode steps, so one long prompt
    never stalls the running decode lanes.
  * **Decode** stays GEMV-shaped: one token per active lane per step.

KV memory defaults to the **paged** layout (`kv_layout="paged"`): each
full-attention layer holds a block pool `(num_blocks, block_size, H, D)`
plus per-lane block tables, so residency scales with live tokens instead
of `slots * max_len`, and finished lanes return their blocks to the free
list immediately. Block 0 is the shared trash block — padding rows and
idle lanes write there. Attention reads the pool through a gather-free
`dynamic_slice` walk (models/layers.py), and the paged decode is
bit-identical to the contiguous oracle (`kv_layout="contiguous"`), which
is kept both as the correctness reference and for sliding-window /
recurrent state (those layers always stay contiguous — their residency
is already bounded).

Finished lanes (EOS or max_tokens) are recycled immediately — the decode
batch never drains waiting for stragglers, which is the serving-side
analogue of the paper's pipeline never idling between vector elements
(Table III).

**Fault tolerance.** Resource pressure no longer has a single terminal
answer (`finish_reason="cache_full"`); the engine degrades instead:

  * **Deadlines** — `Request.deadline_steps` is a scheduler-step budget
    from submission; expired requests finish with
    `finish_reason="deadline"` at the schedule and decode boundaries
    (never mid-token), keeping whatever tokens they already produced.
  * **Backpressure** — `max_queue` bounds the admission queue; an
    overflowing submit is shed immediately with
    `finish_reason="rejected"` instead of growing the queue without
    bound (sheds are drained into the `run`/`step` done list).
  * **Preemption with recompute** — decode-time block exhaustion evicts
    the lowest-priority active lane (lowest `Request.priority`, then
    youngest activation): its paged blocks return to the free list, its
    table rows trash-reset, and it requeues at the head to re-prefill
    from prompt + already-generated tokens. The paged view's
    slot == position invariant makes the recomputed stream
    **token-identical** to an uninterrupted run. `preempt_limit` bounds
    ping-pong; `preempt=False` restores the old terminal behavior.
  * **Tier degradation** — `degrade_ladder` (serving/degrade.py) walks
    rejected/preempted requests down a ladder of registered DotEngine
    modes under queue/KV pressure; `Request.served_tier` records the
    mode actually served, whose `olm_error_bound` still holds.
  * **Integrity + numerics guards** — the block allocator validates
    every id it hands out (in-range, singly-owned) and detects
    double-frees loudly; `integrity_audit=True` additionally audits the
    lane tables each step and recovers corrupted lanes by
    preempt-and-recompute; `numerics_check=True` finishes a lane whose
    logits go NaN/Inf with `finish_reason="numerics"` rather than
    streaming garbage. Both off by default — the fast path is
    unchanged. `serving/faults.py` injects deterministic faults
    against all of this through the `reserve_blocks` /
    `corrupt_table_entry` / `logits_tap` / `prefill_fault` surfaces.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import Counter, deque
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, \
    Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.numerics import EngineSpec, resolve_engine
from repro.models.layers import TRASH_BLOCK, paged_scatter_rows
from repro.models.model import Model

from .degrade import DegradeLadder
from .faults import TransientPrefillError
from .report import ServeReport

__all__ = ["Request", "ServeEngine"]

# Block kinds whose prefill is safe to right-pad: causal attention masks
# padded positions out, and later decode steps overwrite their cache
# slots position-for-position. Recurrent/SSM state advances on every
# token, so padded tails would corrupt it — those families fall back to
# exact-length single-request prefill.
_PAD_SAFE_KINDS = frozenset({"attn", "cross", "xdec"})


def _pow2_bucket(n: int, lo: int = 1) -> int:
    return max(lo, 1 << max(0, math.ceil(math.log2(max(1, n)))))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # Per-request quality tier: a key of the engine's `quality_tiers`
    # mapping (None = the deployment's base numerics). The scheduler
    # keeps decode batches tier-homogeneous, so a request asking for a
    # truncated olm{n}t{p} tier decodes every token under that mode.
    quality_tier: Optional[str] = None
    # Scheduler-step budget from submission (None = no deadline): a
    # request still unfinished `deadline_steps` steps after submit
    # finishes with finish_reason="deadline", keeping its partial
    # output. Enforced at the schedule/decode boundaries, never
    # mid-token, so a deadlined stream is a prefix of the full stream.
    deadline_steps: Optional[int] = None
    # Preemption victim ordering: lower priority is evicted first when
    # the block pool runs dry (ties: youngest activation, then highest
    # rid). Priority does not reorder the FIFO admission queue.
    priority: int = 0
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    t_queue: float = 0.0                # seconds waited before prefill
    # eos | length | max_len | cache_full | deadline | rejected |
    # numerics | failed
    finish_reason: Optional[str] = None
    # scheduler-step stamps: deterministic virtual-time analogues of the
    # wall-clock fields, used by the replay bench so its committed
    # baseline doesn't depend on host speed.
    s_submit: Optional[int] = None
    s_first: Optional[int] = None
    s_done: Optional[int] = None
    # robustness bookkeeping (filled by the engine):
    n_preempts: int = 0                 # times evicted + requeued
    n_retries: int = 0                  # transient prefill retries
    served_tier: Optional[str] = None   # DotEngine mode actually served
    degrade_rung: int = 0               # ladder rung actually served
    # engine-internal: effective tier after degradation (a key of the
    # engine's quality_tiers map; None = the request's own tier).
    eff_tier: Optional[str] = None


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 512, greedy: bool = True,
                 dot_mode: Optional[str] = None,
                 dot_tiling: Union[str, Dict[str, object], None] = None,
                 kv_layout: str = "paged",
                 kv_block_size: int = 16,
                 kv_blocks: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 prefill_bucket_min: int = 8,
                 quality_tiers: Optional[Dict[str, str]] = None,
                 max_queue: Optional[int] = None,
                 preempt: bool = True,
                 preempt_limit: int = 8,
                 numerics_check: bool = False,
                 integrity_audit: bool = False,
                 prefill_retries: int = 3,
                 prefill_backoff: int = 1,
                 degrade_ladder: Optional[Sequence[str]] = None,
                 degrade_free_frac: float = 0.25,
                 degrade_queue_headroom: Optional[int] = None,
                 engine: Optional[EngineSpec] = None,
                 mesh=None):
        # Per-deployment numerics override: serve the same checkpoint under
        # any registered DotEngine mode — every configs/olm_array
        # ARRAY_PRECISIONS width ("olm8" .. "olm32") routes decode GEMMs
        # through the fused inner-product array; the n = 24/32 modes
        # transparently use the wide (int64/two-limb) stream decode —
        # without touching the model config or the engine's
        # interpret/use_pallas deployment knobs.
        # dot_tiling tunes the olm grid kernel per deployment:
        # the string "auto" (or {"tiling": "auto"}) turns on the
        # shape-aware autotuner so prefill GEMMs and decode GEMVs each
        # get their own (block_m, block_n) output tile — k_tile stays
        # at the numerics default, so auto never changes outputs;
        # explicit k_tile / block_m / block_n pins override it (e.g.
        # widen block_n for the fat decode GEMVs). Params are unchanged
        # — the digit modes quantize at use from the stored dtype.
        # EngineSpec front door: `engine=` is the unified declarative
        # form of the legacy dot_mode/dot_tiling/quality_tiers/
        # degrade_ladder kwargs (core.numerics.EngineSpec), resolved
        # against the model's engine. A user-supplied spec is taken as
        # written — no auto-clearing of block pins; say tiling="auto"
        # with unset blocks to mean "autotune". `mesh=` (or spec.mesh +
        # spec.shard) routes the olm GEMMs through the mesh-sharded
        # shard_map dispatch, tiers included. The legacy kwargs below
        # keep their exact historical semantics but now build an
        # EngineSpec internally — every construction path resolves
        # through core.numerics.resolve_engine.
        if engine is not None:
            if (dot_mode is not None or dot_tiling is not None
                    or quality_tiers is not None
                    or degrade_ladder is not None):
                raise ValueError(
                    "pass either engine= (EngineSpec) or the legacy "
                    "dot_mode/dot_tiling/quality_tiers/degrade_ladder "
                    "kwargs, not both")
            eng = resolve_engine(engine, base=model.eng, mesh=mesh)
            if eng != model.eng:
                model = Model(model.cfg, eng)
            if engine.quality_tiers is not None:
                quality_tiers = dict(engine.quality_tiers)
            if engine.degrade_ladder is not None:
                degrade_ladder = tuple(engine.degrade_ladder)
        else:
            if isinstance(dot_tiling, str):
                if dot_tiling != "auto":
                    raise ValueError(
                        f"unknown dot_tiling {dot_tiling!r}: the only "
                        "string form is 'auto' (or pass a dict of knobs)")
                dot_tiling = {"tiling": "auto"}
            override = dict(dot_tiling or {})
            if bad := set(override) - {"k_tile", "block_m", "block_n",
                                       "tiling"}:
                raise ValueError(f"unknown dot_tiling knobs: {sorted(bad)}")
            if override.get("tiling") == "auto":
                # Asking for the autotuner must actually engage it: clear
                # the block knobs the model's engine had pinned (explicit
                # knobs win over auto inside the engine, so stale static
                # pins would silently turn "auto" into a no-op). Blocks
                # are pure perf, so clearing them is safe; a pinned
                # k_tile is a numerics choice (quantization slice width /
                # tree depth) and survives — auto would supply the same
                # default anyway unless the model builder pinned it
                # deliberately. Knobs passed in this same dot_tiling dict
                # survive too. (An explicit None in the spec means
                # "clear the pin" — EngineSpec's _UNSET sentinel keeps
                # it distinct from "inherit".)
                for knob in ("block_m", "block_n"):
                    override.setdefault(knob, None)
            if dot_mode is not None and dot_mode != model.eng.mode:
                override["mode"] = dot_mode
            if override or mesh is not None:
                eng = resolve_engine(EngineSpec(**override),
                                     base=model.eng, mesh=mesh)
                if eng != model.eng:
                    model = Model(model.cfg, eng)
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        # quality_tiers maps tier name -> DotEngine mode: one checkpoint
        # served at several numerics levels (e.g. {"fast": "olm32t20"}
        # as a truncated throughput tier next to the base olm32).
        # Params are shared — digit modes quantize at use — so a tier is
        # just a Model view with a replaced engine plus its own jitted
        # prefill/decode entry points; the scheduler keeps batches
        # tier-homogeneous (below). Tier None is the base deployment.
        self.quality_tiers = dict(quality_tiers or {})

        # Tier-degradation ladder: rungs 1.. are registered as internal
        # quality tiers keyed by their mode name, so a degraded request
        # rides the existing tier-homogeneous scheduler unchanged and is
        # served exactly as a dedicated deployment at that mode would
        # serve it.
        self.degrade: Optional[DegradeLadder] = None
        if degrade_ladder is not None:
            headroom = (max(1, slots) if degrade_queue_headroom is None
                        else degrade_queue_headroom)
            self.degrade = DegradeLadder.build(
                degrade_ladder, base_mode=model.eng.mode,
                free_frac=degrade_free_frac, queue_headroom=headroom)
            for m in self.degrade.ladder[1:]:
                if self.quality_tiers.setdefault(m, m) != m:
                    raise ValueError(
                        f"degrade_ladder rung {m!r} collides with a "
                        f"quality tier of the same name mapped to mode "
                        f"{self.quality_tiers[m]!r}")
        self._active_tier: Optional[str] = None
        self._tier_models: Dict[Optional[str], Model] = {}
        self._tier_fns: Dict[Optional[str], tuple] = {}

        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None: unbounded)")
        if preempt_limit < 1:
            raise ValueError("preempt_limit must be >= 1")
        if prefill_retries < 0 or prefill_backoff < 0:
            raise ValueError("prefill_retries/prefill_backoff must be >= 0")
        self.max_queue = max_queue
        self.preempt = preempt
        self.preempt_limit = preempt_limit
        self.numerics_check = numerics_check
        self.integrity_audit = integrity_audit
        self.prefill_retries = prefill_retries
        self.prefill_backoff = prefill_backoff
        # Robustness event counters (recoveries; terminal finish_reason
        # counts also land here, keyed by the reason string).
        self.counters: Counter = Counter()
        # Requests shed at submit (finish_reason="rejected"); drained
        # into the done list at the next step()/run() boundary.
        self.shed: Deque[Request] = deque()
        # Fault-injection / instrumentation surfaces (serving/faults.py):
        # logits_tap(lg_np, phase, step) -> lg_np runs host-side on the
        # raw logits; prefill_fault(step, reqs) may raise
        # TransientPrefillError to exercise the retry/backoff path.
        self.logits_tap: Optional[Callable] = None
        self.prefill_fault: Optional[Callable] = None
        self._prefill_backoff_until = 0

        cfg = model.cfg
        kinds = tuple(cfg.block_pattern) + tuple(cfg.remainder_blocks)
        # pow2 prompt bucketing needs right-padding to be harmless; see
        # _PAD_SAFE_KINDS. Sliding-window models are excluded too: a pad
        # tail longer than the window would wrap the ring and overwrite
        # still-in-window positions. Both degrade to exact-length
        # per-request prefill (the pre-bucketing behavior).
        self._bucketed = (all(k in _PAD_SAFE_KINDS for k in kinds)
                          and cfg.sliding_window is None)
        self.prefill_bucket_min = prefill_bucket_min

        if prefill_chunk is not None:
            if not self._bucketed:
                raise ValueError(
                    "prefill_chunk requires an attention-only block "
                    "pattern (recurrent/SSM state can't be chunk-padded)")
            if cfg.sliding_window is not None:
                raise ValueError(
                    "prefill_chunk is not supported with sliding_window "
                    "(ring caches can't take chunked writes)")
            if prefill_chunk < 1 or max_len % prefill_chunk != 0:
                raise ValueError(
                    f"prefill_chunk must divide max_len ({max_len}); "
                    f"got {prefill_chunk}")
        self.prefill_chunk = prefill_chunk

        if kv_layout not in ("paged", "contiguous"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        self.kv_layout = kv_layout
        self.kv_block_size = kv_block_size
        self._table: Optional[np.ndarray] = None
        self._table_dirty = False
        if kv_layout == "paged":
            bs = kv_block_size
            if bs < 1:
                raise ValueError("kv_block_size must be >= 1")
            mbl = -(-max_len // bs)        # blocks per lane at max_len
            self.blocks_per_lane = mbl
            if kv_blocks is None:
                # usable default: every lane can reach half depth at once,
                # and any single lane can reach full max_len (so slots=1
                # engines can never hit cache_full) — plus the trash block
                kv_blocks = 1 + max(mbl, -(-slots * mbl // 2))
            if kv_blocks < 2:
                raise ValueError("kv_blocks must be >= 2 (trash + 1 usable)")
            self.kv_blocks = kv_blocks
            self.cache = model.init_cache(
                slots, max_len,
                paged={"num_blocks": kv_blocks, "block_size": bs})
            # host-side allocator: block ids 1..kv_blocks-1 are usable
            # (0 is the trash block); LIFO free list so tests can observe
            # block reuse deterministically
            self._free: List[int] = list(range(kv_blocks - 1, 0, -1))
            self._owned: Dict[int, List[int]] = {s: [] for s in range(slots)}
            self._table = np.full((slots, mbl), TRASH_BLOCK, np.int32)
            self.blocks_peak_used = 0
            # Integrity shadow state: every usable block is in exactly
            # one of {free, owned-by-one-lane, held}. _owner/_free_set
            # let alloc/free validate ids in O(1) and detect double
            # frees loudly; _held tracks blocks reserved out of the pool
            # (fault injection / future prefix-cache pinning).
            self._owner: Dict[int, int] = {}
            self._free_set = set(self._free)
            self._held: set = set()
        else:
            self.kv_blocks = 0
            self.blocks_per_lane = 0
            self.blocks_peak_used = 0
            self._owner = {}
            self._free_set = set()
            self._held = set()
            self.cache = model.init_cache(slots, max_len)
        self.active: Dict[int, Request] = {}       # slot -> request
        self.pos = np.zeros((slots,), np.int32)
        self.last_tok = np.zeros((slots,), np.int32)
        self.queue: Deque[Request] = deque()
        self.memory = None                          # encdec/vlm stub memory
        self.step_count = 0
        self.pending_chunk: Optional[Dict[str, Any]] = None

        # Compile counters: the wrapped bodies bump the counter at trace
        # time, i.e. exactly once per compiled input signature — this is
        # what the prefill-bucket compile-count test observes.
        self.prefill_traces = 0
        self.decode_traces = 0

        def _make_fns(m: Model):
            def _decode_fn(p, t, ps, c, mem):
                self.decode_traces += 1
                return m.decode_step(p, t, ps, c, mem)

            def _prefill_fn(p, b, c, li):
                self.prefill_traces += 1
                return m.prefill(p, b, c, last_index=li)

            def _chunk_fn(p, b, c, st, li):
                self.prefill_traces += 1
                return m.prefill_chunk(p, b, c, st, last_index=li)

            return (jax.jit(_decode_fn), jax.jit(_prefill_fn),
                    jax.jit(_chunk_fn))

        # Tiers naming the base mode share the base Model and its jitted
        # entry points, so adding a redundant tier costs no compiles.
        by_mode: Dict[str, tuple] = {}
        for tier, mode in ([(None, model.eng.mode)]
                           + sorted(self.quality_tiers.items())):
            if mode not in by_mode:
                m = model if mode == model.eng.mode else Model(
                    model.cfg, dataclasses.replace(model.eng, mode=mode))
                by_mode[mode] = (m, _make_fns(m))
            self._tier_models[tier], self._tier_fns[tier] = by_mode[mode]
        self._scatter = jax.jit(self._scatter_fn)

    # The jitted entry points of whichever tier currently owns the
    # lanes; tier switches only happen in _schedule_prefill while the
    # engine is idle, so every decode batch is tier-homogeneous.
    @property
    def _decode(self):
        return self._tier_fns[self._active_tier][0]

    @property
    def _prefill(self):
        return self._tier_fns[self._active_tier][1]

    @property
    def _prefill_chunk(self):
        return self._tier_fns[self._active_tier][2]

    # ------------- client API -------------
    def submit(self, req: Request):
        P = len(req.prompt)
        if P < 1 or P > self.max_len - 1:
            raise ValueError(
                f"prompt length {P} outside [1, max_len-1={self.max_len - 1}]")
        if req.quality_tier is not None \
                and req.quality_tier not in self.quality_tiers:
            raise ValueError(
                f"unknown quality_tier {req.quality_tier!r}; configured "
                f"tiers: {sorted(self.quality_tiers) or 'none'}")
        if req.deadline_steps is not None and req.deadline_steps < 1:
            raise ValueError(
                f"deadline_steps must be >= 1, got {req.deadline_steps}")
        req.t_submit = time.monotonic()
        req.s_submit = self.step_count
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            # Backpressure: past the hard bound, try re-admitting one
            # ladder rung down (bounded extra headroom); otherwise shed
            # with finish_reason="rejected" — never grow without bound.
            if (self.degrade is not None
                    and len(self.queue)
                    < self.max_queue + self.degrade.queue_headroom
                    and self._downshift(req)):
                self.queue.append(req)
                return True
            self._finish(None, req, "rejected", self.shed)
            return False
        self.queue.append(req)
        return True

    def run(self, *, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        steps = 0
        while (self.queue or self.active or self.pending_chunk) \
                and steps < max_steps:
            self.step(done)
            steps += 1
        self._drain_shed(done)
        return done

    def step(self, done: List[Request]):
        """One scheduler iteration: advance/admit prefill work, then one
        batched decode step for every active lane. Exposed so drivers
        (the traffic-replay bench) can interleave submissions."""
        self._drain_shed(done)
        if self.integrity_audit and self.kv_layout == "paged":
            self._audit_tables(done)
        self._schedule_prefill(done)
        if self.active:
            self._decode_step(done)
        self.step_count += 1

    def _drain_shed(self, done: List[Request]):
        while self.shed:
            done.append(self.shed.popleft())

    # ------------- block allocator (paged layout) -------------
    @property
    def free_blocks(self) -> int:
        return len(self._free) if self.kv_layout == "paged" else 0

    def owned_blocks(self, slot: int) -> List[int]:
        return list(self._owned[slot]) if self.kv_layout == "paged" else []

    def _note_usage(self):
        used = (self.kv_blocks - 1) - len(self._free)
        self.blocks_peak_used = max(self.blocks_peak_used, used)

    def _alloc_blocks(self, slot: int, n: int) -> bool:
        """Give `slot` its next n blocks; all-or-nothing. Every id the
        free list yields is validated (in-range, not currently owned)
        before it can reach a lane table."""
        if len(self._free) < n:
            return False
        for _ in range(n):
            bid = self._free.pop()
            self._free_set.discard(bid)
            if not 1 <= bid < self.kv_blocks or bid in self._owner:
                raise RuntimeError(
                    f"block-allocator integrity: free list yielded block "
                    f"{bid} (usable range [1, {self.kv_blocks}), owner "
                    f"{self._owner.get(bid)!r}) — free list corrupted")
            self._owner[bid] = slot
            j = len(self._owned[slot])
            self._owned[slot].append(bid)
            self._table[slot, j] = bid
        self._table_dirty = True
        self._note_usage()
        return True

    def _free_slot_blocks(self, slot: int):
        owned = self._owned[slot]
        if owned:
            for bid in owned:
                if bid in self._free_set or self._owner.get(bid) != slot:
                    why = ("already in the free list" if bid in self._free_set
                           else f"owned by lane {self._owner.get(bid)!r}")
                    raise RuntimeError(
                        f"double-free: lane {slot} freeing block {bid} "
                        f"which is {why} — allocator state corrupted")
                del self._owner[bid]
            self._free.extend(reversed(owned))
            self._free_set.update(owned)
            self._owned[slot] = []
            self._table[slot, :] = TRASH_BLOCK
            self._table_dirty = True

    def reserve_blocks(self, n: int) -> List[int]:
        """Take up to n blocks out of the free pool (fault injection /
        future prefix-cache pinning); they count as used until
        release_blocks returns them. Returns the reserved ids."""
        if self.kv_layout != "paged":
            raise ValueError("reserve_blocks requires kv_layout='paged'")
        ids: List[int] = []
        for _ in range(min(n, len(self._free))):
            bid = self._free.pop()
            self._free_set.discard(bid)
            self._held.add(bid)
            ids.append(bid)
        self._note_usage()
        return ids

    def release_blocks(self, ids: Sequence[int]):
        """Return blocks taken by reserve_blocks to the free pool."""
        for bid in ids:
            if bid not in self._held:
                raise RuntimeError(
                    f"release_blocks: block {bid} was not reserved")
            self._held.discard(bid)
            self._free.append(bid)
            self._free_set.add(bid)

    def corrupt_table_entry(self, slot: int, j: int, bid: int):
        """FAULT-INJECTION surface: overwrite one host block-table entry
        (and flush it to the device) bypassing the allocator guards,
        simulating table corruption. The integrity audit
        (integrity_audit=True) detects and recovers it."""
        if self.kv_layout != "paged":
            raise ValueError("corrupt_table_entry requires kv_layout='paged'")
        self._table[slot, j] = bid
        self._table_dirty = True
        self._flush_tables()

    def _audit_tables(self, done: List[Request]):
        """Step-boundary integrity audit + recovery: a lane whose table
        row disagrees with the allocator's owned list (foreign or
        out-of-range id, lost entry) is repaired — an active lane is
        preempted and recomputes from its accumulated tokens (which the
        paged slot==position invariant makes bit-identical), an idle
        lane's row is rebuilt from the allocator's truth. Faults inject
        at the step boundary and the audit runs at step start, so a
        corrupted entry is never used for a cache write or read."""
        mbl = self.blocks_per_lane
        for slot in range(self.slots):
            owned = self._owned[slot]
            want = owned + [TRASH_BLOCK] * (mbl - len(owned))
            if list(self._table[slot]) == want:
                continue
            self.counters["table_repairs"] += 1
            req = self.active.get(slot)
            if req is not None:
                self._preempt(slot, req, done)
            else:
                self._table[slot, :] = TRASH_BLOCK
                self._table[slot, :len(owned)] = owned
                self._table_dirty = True

    def _integrity_ok(self) -> bool:
        """Self-check: usable blocks partition into free/owned/held with
        no duplicates, shadow maps agree, and every lane table row is
        its owned list followed by trash padding."""
        if self.kv_layout != "paged":
            return True
        free, held = set(self._free), set(self._held)
        owned_all = [b for blks in self._owned.values() for b in blks]
        owned = set(owned_all)
        if len(free) != len(self._free) or len(owned) != len(owned_all):
            return False  # duplicate ids inside one class
        if (free & owned) or (free & held) or (owned & held):
            return False  # a block in two classes at once
        if free | owned | held != set(range(1, self.kv_blocks)):
            return False  # lost or out-of-range blocks
        if free != self._free_set:
            return False
        if any(self._owner.get(b) != s
               for s, blks in self._owned.items() for b in blks) \
                or len(self._owner) != len(owned):
            return False
        mbl = self.blocks_per_lane
        return all(
            list(self._table[s]) == self._owned[s]
            + [TRASH_BLOCK] * (mbl - len(self._owned[s]))
            for s in range(self.slots))

    def _flush_tables(self):
        """Push the host-side block tables into the device cache pytree.
        Must run before any decode step that follows an alloc/free: a
        freed lane's stale table row would route its idle-lane writes
        into blocks now owned by someone else."""
        if not self._table_dirty:
            return
        t = jnp.asarray(self._table)

        def walk(node):
            if isinstance(node, dict):
                if "kpool" in node:
                    tt = t if node["table"].ndim == 2 else \
                        jnp.broadcast_to(t[None], node["table"].shape)
                    return {**node, "table": tt}
                return {k: walk(v) for k, v in node.items()}
            if isinstance(node, tuple):
                return tuple(walk(v) for v in node)
            if isinstance(node, list):
                return [walk(v) for v in node]
            return node

        self.cache = walk(self.cache)
        self._table_dirty = False

    # ------------- robustness helpers -------------
    def _req_tokens(self, req: Request) -> np.ndarray:
        """Tokens to prefill for a request: the prompt, plus — after a
        preemption — everything it already generated, so the recomputed
        lane resumes at exactly the pre-eviction position (the paged
        slot==position invariant makes the resumed stream
        bit-identical to an uninterrupted run)."""
        if not req.output:
            return np.asarray(req.prompt, np.int32)
        return np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(req.output, np.int32)])

    def _tier_of(self, req: Request) -> Optional[str]:
        """Effective scheduling tier: the degraded tier if the ladder
        downshifted this request, else its own quality_tier."""
        return req.eff_tier if req.eff_tier is not None else req.quality_tier

    def _tier_mode(self, tier: Optional[str]) -> str:
        return self._tier_models[tier].eng.mode

    def _downshift(self, req: Request) -> bool:
        """Move a request one ladder rung down (tracked via eff_tier, a
        mode-named internal quality tier). False at the bottom rung."""
        if self.degrade is None:
            return False
        rung = self.degrade.rung_of(self._tier_mode(self._tier_of(req)))
        nxt = self.degrade.next_mode(rung)
        if nxt is None:
            return False
        req.eff_tier = nxt
        req.degrade_rung = rung + 1
        self.counters["degraded"] += 1
        return True

    def _expired(self, req: Request) -> bool:
        return (req.deadline_steps is not None
                and req.s_submit is not None
                and self.step_count - req.s_submit >= req.deadline_steps)

    def _purge_queue_deadlines(self, done: List[Request]):
        if not any(r.deadline_steps is not None for r in self.queue):
            return
        kept: Deque[Request] = deque()
        for req in self.queue:
            if self._expired(req):
                self._finish(None, req, "deadline", done)
            else:
                kept.append(req)
        self.queue = kept

    def _pick_victim(self) -> Tuple[int, Request]:
        """Deterministic preemption victim among active lanes: lowest
        priority first, then youngest activation, then highest rid."""
        return min(self.active.items(),
                   key=lambda kv: (kv[1].priority,
                                   -(kv[1].s_first or 0), -kv[1].rid))

    def _preempt(self, slot: int, req: Request, done: List[Request]):
        """Evict an active lane: free its paged blocks (trash-resetting
        its table row), requeue it at the head to re-prefill from its
        accumulated tokens. Past preempt_limit the eviction becomes
        terminal (cache_full) to bound ping-pong. Under KV pressure a
        requeued request downshifts one degrade-ladder rung."""
        self.active.pop(slot, None)
        self.pos[slot] = 0
        self.last_tok[slot] = 0
        if self.kv_layout == "paged":
            self._free_slot_blocks(slot)
        if req.n_preempts >= self.preempt_limit:
            self._finish(None, req, "cache_full", done)
            return
        req.n_preempts += 1
        self.counters["preempted"] += 1
        if self.degrade is not None and self.degrade.kv_pressure(
                self.free_blocks, self.kv_blocks - 1):
            self._downshift(req)
        self.queue.appendleft(req)

    # ------------- prefill scheduling -------------
    def _schedule_prefill(self, done: List[Request]):
        self._purge_queue_deadlines(done)
        if self.pending_chunk is not None:
            self._advance_chunk(done)
            return
        if self.step_count < self._prefill_backoff_until:
            return  # backing off after a transient prefill failure
        free = [s for s in range(self.slots) if s not in self.active]
        if not free or not self.queue:
            return
        head = self.queue[0]
        # Tier-homogeneous batching: lanes decode under one tier's
        # jitted step, so a head asking for a different tier waits for
        # the running lanes to drain (strict FIFO — later same-tier
        # requests don't jump it); an idle engine adopts the head's
        # tier for the next wave.
        if self.active and self._tier_of(head) != self._active_tier:
            return
        if not self.active:
            self._active_tier = self._tier_of(head)
        if self.prefill_chunk \
                and len(self._req_tokens(head)) > self.prefill_chunk:
            self._start_chunk(free[0], done)
            return
        batch: List[Tuple[int, Request]] = []
        for slot in free:
            if not self.queue:
                break
            req = self.queue[0]
            if self._tier_of(req) != self._active_tier:
                break  # tier boundary: next wave, after lanes drain
            toks = self._req_tokens(req)
            if self.prefill_chunk and len(toks) > self.prefill_chunk:
                break  # long prompt: chunked on a later step, alone
            if self.kv_layout == "paged":
                need = -(-len(toks) // self.kv_block_size)
                if not self._alloc_blocks(slot, need):
                    if not batch and not self.active \
                            and need > self.kv_blocks - 1:
                        # the whole pool can't hold this prompt even
                        # when idle: it can never be served (transient
                        # shortfalls — reserved blocks, other lanes —
                        # wait instead)
                        self.queue.popleft()
                        self._finish(None, req, "cache_full", done)
                        continue
                    break  # wait for blocks to come back
            self.queue.popleft()
            batch.append((slot, req))
            if not self._bucketed:
                break  # exact-length prefill: one request per call
        if batch:
            self._prefill_batch(batch, done)

    def _prefill_batch(self, batch: List[Tuple[int, Request]],
                       done: List[Request]):
        """One batched GEMM-shaped prefill over up to len(free-slots)
        waiting requests, padded to pow2 (rows, length) buckets."""
        t_start = time.monotonic()
        if self.prefill_fault is not None:
            try:
                self.prefill_fault(self.step_count, [r for _, r in batch])
            except TransientPrefillError:
                self._prefill_retry(batch, done)
                return
        seqs = [self._req_tokens(r) for _, r in batch]
        lens = [len(s) for s in seqs]
        n = len(batch)
        if self._bucketed:
            Sb = min(_pow2_bucket(max(lens), self.prefill_bucket_min),
                     self.max_len)
            Bp = _pow2_bucket(n)
        else:
            Sb, Bp = max(lens), n
        tokens = np.zeros((Bp, Sb), np.int32)
        last_idx = np.zeros((Bp,), np.int32)
        slot_ids = np.zeros((Bp,), np.int32)
        valid = np.zeros((Bp,), bool)
        for i, (slot, req) in enumerate(batch):
            tokens[i, :lens[i]] = seqs[i]
            last_idx[i] = lens[i] - 1
            slot_ids[i] = slot
            valid[i] = True
        row_cache = self.model.init_cache(Bp, Sb)
        logits, row_cache, _mem = self._prefill(
            self.params, {"tokens": jnp.asarray(tokens)}, row_cache,
            jnp.asarray(last_idx))
        if self.logits_tap is not None or self.numerics_check:
            lg = np.asarray(logits)
            if self.logits_tap is not None:
                lg = self.logits_tap(lg, "prefill", self.step_count)
            if self.numerics_check:
                finite = np.isfinite(lg).all(axis=-1)
                for i, (slot, req) in enumerate(batch):
                    if not finite[i]:
                        # bad row: never scattered, never activated
                        valid[i] = False
                        if self.kv_layout == "paged":
                            self._free_slot_blocks(slot)
                        self._finish(None, req, "numerics", done)
            with np.errstate(invalid="ignore"):
                toks = lg.argmax(axis=-1).astype(np.int32)
        else:
            toks = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self._scatter_rows(row_cache, slot_ids, valid, Sb)
        now = time.monotonic()
        for i, (slot, req) in enumerate(batch):
            if not valid[i]:
                continue  # finished above (numerics)
            req.t_queue = t_start - req.t_submit
            self._activate(slot, req, int(toks[i]), lens[i], now, done)

    def _prefill_retry(self, batch: List[Tuple[int, Request]],
                       done: List[Request]):
        """Transient prefill failure: release the batch's blocks, return
        it to the queue head in arrival order, and back off
        exponentially (prefill_backoff * 2**(attempt-1) steps). A
        request past prefill_retries finishes with reason "failed"."""
        self.counters["prefill_retries"] += 1
        for slot, req in reversed(batch):
            if self.kv_layout == "paged":
                self._free_slot_blocks(slot)
            req.n_retries += 1
            if req.n_retries > self.prefill_retries:
                self._finish(None, req, "failed", done)
            else:
                self.queue.appendleft(req)
        attempt = max(r.n_retries for _, r in batch)
        self._prefill_backoff_until = (
            self.step_count + self.prefill_backoff * (1 << (attempt - 1)))

    def _activate(self, slot: int, req: Request, first_tok: int, P: int,
                  now: float, done: List[Request]):
        req.output.append(first_tok)
        if req.t_first is None:
            # a preempted request's TTFT is its *first* activation
            req.t_first = now
            req.s_first = self.step_count
        req.served_tier = self._tier_mode(self._active_tier)
        self.last_tok[slot] = first_tok
        self.pos[slot] = P
        self.active[slot] = req
        reason = self._finish_reason(req, first_tok, P)
        if reason:
            self._finish(slot, req, reason, done)

    def _scatter_rows(self, row_cache, slot_ids, valid, Sb):
        """Scatter a fresh (Bp, Sb) row cache into the lane pool. Paged
        attention layers take the block route (padding and dummy rows land
        in the trash block); everything else (contiguous k/v, SWA rings,
        recurrent state) is written per-lane with a validity guard."""
        blk_tables = None
        if self.kv_layout == "paged":
            bs = self.kv_block_size
            nb = -(-Sb // bs)
            bt = np.full((len(slot_ids), nb), TRASH_BLOCK, np.int32)
            for i, slot in enumerate(slot_ids):
                if valid[i]:
                    owned = self._owned[int(slot)]
                    take = min(len(owned), nb)
                    bt[i, :take] = owned[:take]
            blk_tables = jnp.asarray(bt)
        self.cache = self._scatter(
            self.cache, row_cache, jnp.asarray(slot_ids),
            jnp.asarray(valid), blk_tables)
        if self.kv_layout == "paged":
            self._flush_tables()

    def _scatter_fn(self, pool_cache, row_cache, slot_ids, valid,
                    blk_tables):
        """Jitted structural scatter of row_cache rows into pool_cache
        lanes. Leaves under {"scan"} carry a leading pattern-group axis
        (batch axis 1), {"rem"} leaves don't (batch axis 0); "len"
        scalars max-combine; paged layers get the block-pool scatter."""
        Bp = slot_ids.shape[0]

        def put(pool, row, axis):
            zero = jnp.zeros((), slot_ids.dtype)
            for i in range(Bp):
                ri = jax.lax.dynamic_slice_in_dim(row, i, 1, axis)
                start = [zero] * pool.ndim
                start[axis] = slot_ids[i]
                cur = jax.lax.dynamic_slice(pool, tuple(start), ri.shape)
                upd = jnp.where(valid[i], ri.astype(pool.dtype), cur)
                pool = jax.lax.dynamic_update_slice(pool, upd, tuple(start))
            return pool

        def walk(pn, rn, stacked):
            if pn is None:
                return None
            if isinstance(pn, dict):
                if "kpool" in pn:
                    f = paged_scatter_rows
                    if stacked:
                        f = jax.vmap(f, in_axes=(0, 0, None))
                    return {"kpool": f(pn["kpool"], rn["k"], blk_tables),
                            "vpool": f(pn["vpool"], rn["v"], blk_tables),
                            "table": pn["table"],
                            "len": jnp.maximum(pn["len"], rn["len"])}
                return {k: (jnp.maximum(pn[k], rn[k]) if k == "len"
                            else walk(pn[k], rn[k], stacked)) for k in pn}
            return put(pn, rn, 1 if stacked else 0)

        return {
            "scan": tuple(walk(a, b, True) for a, b in
                          zip(pool_cache["scan"], row_cache["scan"])),
            "rem": [walk(a, b, False) for a, b in
                    zip(pool_cache["rem"], row_cache["rem"])],
        }

    # ------------- chunked prefill -------------
    def _start_chunk(self, slot: int, done: List[Request]):
        req = self.queue[0]
        seq = self._req_tokens(req)
        P = len(seq)
        chunk = self.prefill_chunk
        nchunks = -(-P // chunk)
        total = nchunks * chunk            # <= max_len: chunk | max_len
        if self.kv_layout == "paged":
            need = -(-P // self.kv_block_size)
            if not self._alloc_blocks(slot, need):
                if not self.active and need > self.kv_blocks - 1:
                    self.queue.popleft()
                    self._finish(None, req, "cache_full", done)
                return
        self.queue.popleft()
        req.t_queue = time.monotonic() - req.t_submit
        self.pending_chunk = {
            "req": req, "slot": slot, "seq": seq,
            "next": 0, "nchunks": nchunks,
            "row_cache": self.model.init_cache(1, total),
        }

    def _abort_chunk(self) -> Dict[str, Any]:
        """Tear down the in-flight chunk state (deadline / transient
        failure), releasing the lane's blocks; nothing was activated or
        scattered yet, so dropping the row cache loses nothing."""
        c = self.pending_chunk
        self.pending_chunk = None
        if self.kv_layout == "paged":
            self._free_slot_blocks(c["slot"])
        return c

    def _advance_chunk(self, done: List[Request]):
        """Run one prompt chunk; decode lanes keep stepping in between."""
        c = self.pending_chunk
        req, slot, chunk = c["req"], c["slot"], self.prefill_chunk
        if self._expired(req):
            self._abort_chunk()
            self._finish(None, req, "deadline", done)
            return
        if self.prefill_fault is not None:
            try:
                self.prefill_fault(self.step_count, [req])
            except TransientPrefillError:
                # restart from chunk 0 after backoff (fresh row cache,
                # so the retried prefill is deterministic)
                self._abort_chunk()
                self._prefill_retry([(slot, req)], done)
                return
        seq = c["seq"]
        P = len(seq)
        s0 = c["next"] * chunk
        piece = np.zeros((1, chunk), np.int32)
        real = seq[s0:s0 + chunk]
        piece[0, :len(real)] = real
        is_last = c["next"] == c["nchunks"] - 1
        li = np.asarray([(P - 1 - s0) if is_last else chunk - 1], np.int32)
        logits, c["row_cache"] = self._prefill_chunk(
            self.params, {"tokens": jnp.asarray(piece)}, c["row_cache"],
            jnp.asarray(s0, jnp.int32), jnp.asarray(li))
        c["next"] += 1
        if not is_last:
            return
        self.pending_chunk = None
        lg = np.asarray(logits[0])
        if self.numerics_check and not np.isfinite(lg).all():
            if self.kv_layout == "paged":
                self._free_slot_blocks(slot)
            self._finish(None, req, "numerics", done)
            return
        self._scatter_rows(c["row_cache"], np.asarray([slot], np.int32),
                           np.asarray([True]), c["nchunks"] * chunk)
        tok = int(lg.argmax())
        self._activate(slot, req, tok, P, time.monotonic(), done)

    # ------------- decode -------------
    def _finish_reason(self, req: Request, tok: int, pos: int
                       ) -> Optional[str]:
        if req.eos_id is not None and tok == req.eos_id:
            return "eos"
        if len(req.output) >= req.max_new_tokens:
            return "length"
        if pos >= self.max_len - 1:
            return "max_len"
        return None

    def _finish(self, slot: Optional[int], req: Request, reason: str,
                done: List[Request]):
        req.finish_reason = reason
        req.t_done = time.monotonic()
        req.s_done = self.step_count
        self.counters[reason] += 1
        done.append(req)
        if slot is not None:
            self.active.pop(slot, None)
            self.pos[slot] = 0
            self.last_tok[slot] = 0
            if self.kv_layout == "paged":
                self._free_slot_blocks(slot)

    def _ensure_decode_blocks(self, done: List[Request]):
        """Pre-step block allocation: a lane about to write position p
        needs block p // bs. When the pool is dry, preempt the
        lowest-priority active lane (possibly the needy lane itself)
        instead of terminating — preempt=False keeps the old terminal
        cache_full behavior."""
        bs = self.kv_block_size
        for slot, req in sorted(self.active.items()):
            if slot not in self.active:
                continue  # preempted earlier in this pass
            while int(self.pos[slot]) // bs >= len(self._owned[slot]):
                if self._alloc_blocks(slot, 1):
                    break
                if not self.preempt:
                    self._finish(slot, req, "cache_full", done)
                    break
                vslot, vreq = self._pick_victim()
                self._preempt(vslot, vreq, done)
                if vslot == slot:
                    break  # the needy lane itself was evicted

    def _decode_step(self, done: List[Request]):
        for slot, req in list(self.active.items()):
            if self._expired(req):
                self._finish(slot, req, "deadline", done)
        if not self.active:
            return
        if self.kv_layout == "paged":
            self._ensure_decode_blocks(done)
            self._flush_tables()
            if not self.active:
                return
        toks = jnp.asarray(self.last_tok)
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(
            self.params, toks, pos, self.cache, self.memory)
        if self.logits_tap is not None or self.numerics_check:
            lg = np.asarray(logits)
            if self.logits_tap is not None:
                lg = self.logits_tap(lg, "decode", self.step_count)
            if self.numerics_check:
                finite = np.isfinite(lg).all(axis=-1)
                for slot, req in list(self.active.items()):
                    if not finite[slot]:
                        # the poisoned token is never appended: the
                        # stream stays a clean prefix
                        self._finish(slot, req, "numerics", done)
            with np.errstate(invalid="ignore"):
                nxt = lg.argmax(axis=-1).astype(np.int32)
        else:
            nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for slot, req in list(self.active.items()):
            t = int(nxt[slot])
            req.output.append(t)
            self.pos[slot] += 1
            self.last_tok[slot] = t
            reason = self._finish_reason(req, t, int(self.pos[slot]))
            if reason:
                self._finish(slot, req, reason, done)

    # ------------- metrics -------------
    @staticmethod
    def latency_report(done: List[Request]) -> ServeReport:
        """Wall-clock latency summary: mean/p50/p99 TTFT and end-to-end,
        queue wait, and aggregate tokens/s over the span of the batch.
        Returns a ServeReport (empty when nothing finished); see
        serving/report.py for the unified key surface and
        ServeReport.collect for the full deployment summary."""
        if not done:
            return ServeReport()

        def pcts(vals):
            if not vals:
                nan = float("nan")
                return nan, nan, nan
            return (float(np.mean(vals)),
                    float(np.percentile(vals, 50)),
                    float(np.percentile(vals, 99)))

        ttft = [r.t_first - r.t_submit for r in done if r.t_first]
        e2e = [r.t_done - r.t_submit for r in done if r.t_done]
        queue = [r.t_queue for r in done]
        ttft_mean, ttft_p50, ttft_p99 = pcts(ttft)
        e2e_mean, e2e_p50, e2e_p99 = pcts(e2e)
        new_tokens = sum(len(r.output) for r in done)
        t0 = min(r.t_submit for r in done)
        t1 = max((r.t_done for r in done if r.t_done), default=t0)
        span = max(t1 - t0, 1e-9)
        return ServeReport({
            "n": len(done),
            "finish_reasons": ServeReport.finish_reasons(done),
            "ttft_mean_s": ttft_mean,
            "ttft_p50_s": ttft_p50,
            "ttft_p99_s": ttft_p99,
            "e2e_mean_s": e2e_mean,
            "e2e_p50_s": e2e_p50,
            "e2e_p99_s": e2e_p99,
            "queue_wait_mean_s": float(np.mean(queue)),
            "new_tokens": new_tokens,
            "tokens_per_s": new_tokens / span,
        })

    def kv_report(self) -> ServeReport:
        """KV residency accounting: bytes actually resident for attention
        K/V storage under the current layout vs what the contiguous
        `slots * max_len` layout would pin. Deterministic (pure shape
        math), so the replay bench baselines it exactly."""
        kv_keys = {"k", "v", "kpool", "vpool"}

        def nbytes(tree) -> int:
            total = 0

            def walk(node):
                nonlocal total
                if isinstance(node, dict):
                    for key, val in node.items():
                        if key in kv_keys:
                            total += int(np.prod(val.shape)) * val.dtype.itemsize
                        else:
                            walk(val)
                elif isinstance(node, (tuple, list)):
                    for val in node:
                        walk(val)

            walk(tree)
            return total

        resident = nbytes(self.cache)
        contiguous = nbytes(jax.eval_shape(
            lambda: self.model.init_cache(self.slots, self.max_len)))
        return ServeReport({
            "kv_layout": self.kv_layout,
            "kv_bytes_resident": resident,
            "kv_bytes_contiguous": contiguous,
            "kv_block_size": self.kv_block_size if self.kv_layout == "paged" else 0,
            "kv_blocks_usable": max(self.kv_blocks - 1, 0),
            "kv_blocks_free": self.free_blocks,
            "kv_blocks_held": len(self._held),
            "kv_blocks_peak_used": self.blocks_peak_used,
            "integrity_ok": self._integrity_ok(),
        })
