"""ServeReport: the one serving metrics mapping.

Three report surfaces grew up separately — `ServeEngine.latency_report`
(wall-clock latency + finish reasons), `ServeEngine.kv_report` (KV
residency), and the replay harness's `step_report` (virtual-clock
percentiles + robustness counters, each counter under its own `n_*`
key). ServeReport unifies them: every producer returns this mapping, and
consumers (`launch/serve.py`, `serving/replay.py`, the serve benches)
print and index through it.

Canonical keys (producers set the subset that applies):

  n                 finished requests
  finish_reasons    {reason: count} — eos / length / cache_full /
                    deadline / rejected / numerics / failed
  preempts          total preempt-with-recompute events (sum over done)
  retries           total transient prefill retries
  degrades          requests served below their requested tier
  ttft_steps_p50/99, e2e_steps_p50/99, steps_total, tokens_per_step
                    virtual-clock replay metrics (deterministic, gated)
  ttft_*_s, e2e_*_s, queue_wait_mean_s, tokens_per_s
                    wall-clock latency metrics (humans only, never gated)
  new_tokens, wall_s
  kv                nested kv_report mapping (KV residency; collect())
  counters          nested engine event counters (collect())

Backwards compatibility: the legacy `n_*` keys stay readable as aliases
— `n_preempts`/`n_retries`/`n_degraded` resolve to the renamed counters,
and `n_<finish reason>` (e.g. `n_cache_full`, `n_deadline`) resolves to
`finish_reasons[<reason>]` with a 0 default, exactly the old per-reason
counter semantics. Aliases are read-only views: iteration, `items()`,
and JSON serialization expose canonical keys only, so printed reports
have one spelling per fact.

ServeReport subclasses dict, so `json.dumps`, `==` against plain dicts,
and in-place mutation (`report["wall_s"] = ...`) all behave as before.
An empty report equals `{}` — the documented "no finished requests"
value of every producer.
"""
from __future__ import annotations

from typing import Any, Dict, List

__all__ = ["ServeReport"]


class ServeReport(dict):
    # Counters that were renamed (old n_* key -> canonical key).
    _RENAMED = {"n_preempts": "preempts",
                "n_retries": "retries",
                "n_degraded": "degrades"}
    # Legacy per-reason counters now folded into finish_reasons. The
    # alias set is closed over the engine's documented finish reasons so
    # a typo'd key still raises KeyError instead of returning 0.
    _REASONS = frozenset({"eos", "length", "max_len", "cache_full",
                          "deadline", "rejected", "numerics", "failed"})

    def _resolve(self, key: str):
        """Canonical value for a legacy alias, or raise KeyError."""
        if key in self._RENAMED and dict.__contains__(self, self._RENAMED[key]):
            return dict.__getitem__(self, self._RENAMED[key])
        if (isinstance(key, str) and key.startswith("n_")
                and key[2:] in self._REASONS
                and dict.__contains__(self, "finish_reasons")):
            return dict.__getitem__(self, "finish_reasons").get(key[2:], 0)
        raise KeyError(key)

    def __getitem__(self, key):
        if dict.__contains__(self, key):
            return dict.__getitem__(self, key)
        return self._resolve(key)

    def __contains__(self, key):
        if dict.__contains__(self, key):
            return True
        try:
            self._resolve(key)
            return True
        except KeyError:
            return False

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    @staticmethod
    def finish_reasons(done: List[Any]) -> Dict[str, int]:
        """{reason: count} over a finished-request list (shared by the
        latency and step reports so the two can never disagree)."""
        reasons: Dict[str, int] = {}
        for r in done:
            key = r.finish_reason or "unknown"
            reasons[key] = reasons.get(key, 0) + 1
        return reasons

    @classmethod
    def collect(cls, engine, done: List[Any]) -> "ServeReport":
        """Full deployment report: wall-clock latency surface plus the
        nested `kv` residency mapping and engine event `counters` — what
        `launch/serve.py` prints as its one JSON summary line."""
        rep = cls(engine.latency_report(done))
        rep["kv"] = dict(engine.kv_report())
        rep["counters"] = dict(engine.counters)
        return rep
