"""Deterministic fault-injection harness for the serving engine.

A seeded :class:`FaultPlan` schedules four fault families against a
running :class:`~repro.serving.engine.ServeEngine`:

  exhaust       allocator exhaustion — reserve (steal) free KV blocks
                through the engine's ``reserve_blocks`` API for a fixed
                number of scheduler steps, forcing decode-time block
                starvation (and therefore preemption-with-recompute).
  corrupt       block-table corruption — overwrite one live lane-table
                entry with an out-of-range or foreign (alias) block id
                via ``corrupt_table_entry``; the engine's integrity
                audit must detect and recover (preempt + recompute).
  nan           NaN/Inf activations — poison one active lane's decode
                logits at a chosen step through the engine's host-side
                ``logits_tap``; the opt-in numerics guard must finish
                the request with ``finish_reason="numerics"`` instead
                of streaming garbage tokens.
  prefill_fail  transient prefill failure — the engine's
                ``prefill_fault`` gate raises
                :class:`TransientPrefillError` for the next N prefill
                attempts; the engine must retry with bounded backoff
                and eventually serve bit-identical tokens.

Every fire is deterministic: the plan is a pure function of
:class:`FaultConfig` (seeded numpy Generator — stable bit streams), and
the injector's per-step behavior depends only on the engine's own
deterministic scheduler state. An event whose precondition is not yet
met (no active lane, no free block to steal) **defers** to the next
step rather than being dropped, so the same plan resolves the same way
every run; ``stats`` records what actually fired so benches can assert
injected == resolved. Thread a plan through a replay with
``run_replay(engine, workload, faults=FaultInjector(plan))``.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Optional

import numpy as np

__all__ = ["TransientPrefillError", "FaultConfig", "FaultPlan",
           "build_fault_plan", "FaultInjector"]


class TransientPrefillError(RuntimeError):
    """A prefill attempt failed transiently; the engine should retry
    with backoff (raised by fault injection or a real flaky backend)."""


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded fault-plan shape. Steps are scheduler steps (the engine's
    virtual clock), so plans replay identically on any host."""
    seed: int = 0
    horizon_steps: int = 60        # events are scheduled in [2, horizon)
    n_exhaust: int = 1             # allocator-exhaustion events
    exhaust_blocks: int = 64       # blocks stolen per event (capped at free)
    exhaust_hold_steps: int = 8    # steps before stolen blocks return
    n_corrupt: int = 1             # block-table corruption events
    n_nan: int = 1                 # NaN-logits injections (decode step)
    n_prefill_fail: int = 1        # transient prefill-failure events
    prefill_fail_attempts: int = 2  # consecutive failures per event


# A plan is a list of {"kind", "step", ...} events sorted by step. Kept
# as plain dicts so benches can serialize it next to their counters.
FaultPlan = List[Dict[str, int]]


def build_fault_plan(cfg: FaultConfig) -> FaultPlan:
    """Seeded plan: same config -> same events, everywhere."""
    rng = np.random.default_rng(cfg.seed)
    events: FaultPlan = []

    def steps(n):
        lo, hi = 2, max(cfg.horizon_steps, 3)
        return sorted(int(s) for s in rng.integers(lo, hi, n))

    for s in steps(cfg.n_exhaust):
        events.append({"kind": "exhaust", "step": s,
                       "blocks": cfg.exhaust_blocks,
                       "hold": cfg.exhaust_hold_steps})
    for s, alias in zip(steps(cfg.n_corrupt),
                        rng.integers(0, 2, cfg.n_corrupt)):
        events.append({"kind": "corrupt", "step": s, "alias": int(alias)})
    for s in steps(cfg.n_nan):
        events.append({"kind": "nan", "step": s})
    for s in steps(cfg.n_prefill_fail):
        events.append({"kind": "prefill_fail", "step": s,
                       "attempts": cfg.prefill_fail_attempts})
    return sorted(events, key=lambda e: (e["step"], e["kind"]))


class FaultInjector:
    """Drives a FaultPlan against an engine, one scheduler step at a
    time. Call ``attach(engine)`` once, ``apply(engine, step)`` before
    every ``engine.step`` (run_replay does both), and ``finalize``
    after the drive loop to return any still-held blocks."""

    def __init__(self, plan: FaultPlan):
        self.pending: FaultPlan = sorted(plan,
                                         key=lambda e: (e["step"], e["kind"]))
        self.stats: Counter = Counter()
        self._holds: List[Dict[str, object]] = []  # {release, ids}
        self._nan_armed = 0
        self._fail_budget = 0
        self._engine = None

    # ---- engine hooks -------------------------------------------------
    def attach(self, engine) -> "FaultInjector":
        """Install the logits tap and prefill gate. The NaN family needs
        ``numerics_check=True`` on the engine to resolve to an explicit
        finish_reason (asserted here so a plan can't silently stream
        garbage tokens)."""
        if any(e["kind"] == "nan" for e in self.pending) \
                and not engine.numerics_check:
            raise ValueError(
                "FaultPlan injects NaN activations but the engine has "
                "numerics_check=False: the fault would stream garbage "
                "tokens instead of resolving to finish_reason='numerics'")
        self._engine = engine
        engine.logits_tap = self._tap
        engine.prefill_fault = self._prefill_gate
        return self

    def _tap(self, logits: np.ndarray, phase: str, step: int) -> np.ndarray:
        eng = self._engine
        if phase == "decode" and self._nan_armed > 0 and eng.active:
            slot = min(eng.active)          # deterministic victim
            logits = logits.copy()
            logits[slot, :] = np.nan
            self._nan_armed -= 1
            self.stats["nan"] += 1
        return logits

    def _prefill_gate(self, step: int, reqs) -> None:
        if self._fail_budget > 0:
            self._fail_budget -= 1
            self.stats["prefill_fail"] += 1
            raise TransientPrefillError(
                f"injected transient prefill failure at step {step}")

    # ---- per-step drive ----------------------------------------------
    def apply(self, engine, step: int) -> None:
        """Release due block holds, then fire every due event whose
        precondition holds; unmet events defer to the next step."""
        for h in [h for h in self._holds if h["release"] <= step]:
            engine.release_blocks(h["ids"])
            self._holds.remove(h)
        keep: FaultPlan = []
        for e in self.pending:
            if e["step"] > step or not self._fire(engine, e, step):
                keep.append(e)
        self.pending = keep

    def _fire(self, engine, e: Dict[str, int], step: int) -> bool:
        kind = e["kind"]
        if kind == "exhaust":
            if engine.kv_layout != "paged" or engine.free_blocks == 0:
                return False
            ids = engine.reserve_blocks(min(e["blocks"],
                                            engine.free_blocks))
            self._holds.append({"release": step + e["hold"], "ids": ids})
            self.stats["exhaust"] += 1
            return True
        if kind == "corrupt":
            if engine.kv_layout != "paged":
                return False
            owners = sorted(s for s in engine.active
                            if engine.owned_blocks(s))
            if not owners:
                return False
            slot = owners[0]
            bid = engine.kv_blocks + 3              # out of range
            if e["alias"]:                          # foreign live block
                others = [s for s in owners[1:]]
                if others:
                    bid = engine.owned_blocks(others[0])[0]
            engine.corrupt_table_entry(slot, 0, bid)
            self.stats["corrupt"] += 1
            return True
        if kind == "nan":
            if not engine.active:
                return False
            self._nan_armed += 1
            return True
        if kind == "prefill_fail":
            self._fail_budget += e["attempts"]
            self.stats["prefill_fail_events"] += 1
            return True
        raise ValueError(f"unknown fault kind {kind!r}")

    def finalize(self, engine) -> None:
        """Return any still-held blocks (a hold whose release step lies
        past the drain) so post-run KV accounting balances."""
        for h in self._holds:
            engine.release_blocks(h["ids"])
        self._holds.clear()

    def summary(self) -> Dict[str, int]:
        """Fired-fault counters (what actually hit the engine)."""
        return {k: int(v) for k, v in sorted(self.stats.items())}
