"""Graceful tier degradation under resource pressure.

The paper's variable-precision digit slices exist so an inner-product
array can trade accuracy for activity when resources are tight; the
`olm{n}t{p}` truncated modes made that a servable quality tier (PR 8).
This module turns the tier axis into a pressure valve: a configurable
**downshift ladder** of registered DotEngine modes, walked one rung at a
time when the engine is under KV-block or admission-queue pressure —
the serving-side analogue of the approximate-multiplier accuracy/energy
ladder (arxiv 2301.12181).

Rung 0 is the deployment's base mode; rungs 1..R-1 are progressively
cheaper (typically truncated) modes. Every rung must be a registered
DotEngine mode, so `olm_error_bound` stays guaranteed per served tier —
a degraded request is served *exactly* as a dedicated deployment at
that mode would serve it, just with `Request.served_tier` recording the
downgrade.

Downshifts happen at two boundaries (both in ServeEngine):

  * **submit overflow** — a bounded admission queue would shed the
    request with ``finish_reason="rejected"``; with a ladder configured
    and headroom left, the request is re-admitted one rung down
    instead.
  * **preemption requeue** — a preempted lane re-enters the queue; if
    KV-block pressure is above threshold (``free_frac``), it re-admits
    one rung down so its recompute and remaining decode run cheaper.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

__all__ = ["DegradeLadder"]


@dataclasses.dataclass(frozen=True)
class DegradeLadder:
    """Validated tier-downshift ladder.

    ladder     registered DotEngine mode names, rung 0 = the base mode
    free_frac  preempt-requeue downshift threshold: pressure when
               free_blocks / usable_blocks < free_frac
    queue_headroom  extra queue slots granted to degraded re-admission
               past the hard max_queue bound (0 disables re-admission
               of overflow submits)
    """
    ladder: Tuple[str, ...]
    free_frac: float = 0.25
    queue_headroom: int = 1

    @staticmethod
    def build(ladder: Sequence[str], *, base_mode: str,
              free_frac: float = 0.25,
              queue_headroom: int = 1) -> "DegradeLadder":
        from repro.core.numerics import DotEngine
        rungs = tuple(ladder)
        if len(rungs) < 2:
            raise ValueError(
                "degrade_ladder needs >= 2 rungs (base + one downshift "
                f"target); got {list(rungs)}")
        known = DotEngine.modes()
        if bad := [m for m in rungs if m not in known]:
            raise ValueError(
                f"degrade_ladder rungs {bad} are not registered DotEngine "
                f"modes (have {sorted(known)}); every rung must carry a "
                "documented olm_error_bound")
        if rungs[0] != base_mode:
            raise ValueError(
                f"degrade_ladder rung 0 must be the deployment base mode "
                f"{base_mode!r}, got {rungs[0]!r} — the ladder is a "
                "downshift from what the request would otherwise get")
        if len(set(rungs)) != len(rungs):
            raise ValueError(f"degrade_ladder has duplicate rungs: "
                             f"{list(rungs)}")
        if not 0.0 <= free_frac <= 1.0:
            raise ValueError(f"free_frac must be in [0, 1], got {free_frac}")
        if queue_headroom < 0:
            raise ValueError("queue_headroom must be >= 0")
        return DegradeLadder(rungs, free_frac, queue_headroom)

    def rung_of(self, mode: Optional[str]) -> int:
        """Ladder rung of a mode name (requests whose tier mode is not a
        rung start from rung 0 — the ladder is relative to base)."""
        if mode is not None and mode in self.ladder:
            return self.ladder.index(mode)
        return 0

    def next_mode(self, rung: int) -> Optional[str]:
        """Mode one rung down, or None if already at the bottom."""
        if rung + 1 < len(self.ladder):
            return self.ladder[rung + 1]
        return None

    def kv_pressure(self, free_blocks: int, usable_blocks: int) -> bool:
        """KV-block pressure predicate for the preempt-requeue boundary
        (contiguous layouts have no block pool: never under pressure)."""
        if usable_blocks <= 0:
            return False
        return free_blocks < self.free_frac * usable_blocks
