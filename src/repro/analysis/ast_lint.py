"""Engine 2: fast AST lint enforcing repo architecture rules over src/.

Four repo-specific rules (style is ruff's job — see ruff.toml):

  ast-raw-dot              no jnp.dot / lax.dot_general calls outside
                           core/numerics.py: contractions route through
                           DotEngine so olm mode dispatch can't be
                           bypassed.
  ast-x64-config           no jax.config.update("jax_enable_x64", ...)
                           outside compat.py: x64 is scoped via
                           repro.compat.enable_x64, never global.
  ast-transcendental-scale no math.log2 / exp2 / pow calls inside the
                           scale-computation modules: pow2 scales are
                           exponent-field bitcasts, exact on every
                           backend.
  ast-serving-contraction  no contraction calls (einsum / matmul /
                           tensordot, on top of the raw-dot set) inside
                           src/repro/serving/: the serving engine is a
                           scheduler — every GEMM/GEMV must go through
                           the model so the per-deployment dot_mode /
                           dot_tiling override actually governs all
                           serving math (raw lax.dot_general stays
                           confined to core/numerics.py repo-wide).

Import aliases are resolved per module (import jax.numpy as jnp,
from jax import lax, from jax.lax import dot_general, ...) so renaming
an import cannot dodge a rule. Grandfathered sites live in a committed
suppression baseline keyed `rule::relpath::qualname` — moving or adding
a call invalidates its key, so the baseline can only shrink silently,
never grow.
"""
from __future__ import annotations

import ast
import json
import os
from typing import Iterable

from .contracts import Violation

__all__ = ["RAW_DOT_CALLS", "TRANSCENDENTAL_CALLS", "SCALE_MODULES",
           "SERVING_CONTRACTION_CALLS", "SERVING_MODULES_PREFIX",
           "DEFAULT_BASELINE_PATH", "lint_file", "load_baseline",
           "baseline_key", "run"]

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
DEFAULT_BASELINE_PATH = os.path.join(_REPO_ROOT, "tools",
                                     "olmlint_baseline.json")

# Fully-qualified callables each rule bans (post alias resolution).
RAW_DOT_CALLS = frozenset({
    "jax.numpy.dot", "jax.lax.dot", "jax.lax.dot_general",
})
TRANSCENDENTAL_CALLS = frozenset({
    "math.log2", "math.exp2", "math.pow",
    "numpy.exp2", "numpy.log2", "numpy.power",
    "jax.numpy.exp2", "jax.numpy.log2", "jax.numpy.power",
    "jax.lax.exp2", "jax.lax.exp", "jax.lax.log", "jax.lax.pow",
})

# The serving module is a scheduler, not a compute layer: any tensor
# contraction there would bypass the per-deployment dot_mode/dot_tiling
# override (ServeEngine rebuilds the model's DotEngine), so the rule
# bans the wider einsum/matmul family on top of the raw-dot set.
SERVING_CONTRACTION_CALLS = RAW_DOT_CALLS | frozenset({
    "jax.numpy.einsum", "jax.numpy.matmul", "jax.numpy.tensordot",
    "jax.numpy.inner", "jax.numpy.vdot",
})

# repo-relative allowlists / scopes (posix-style paths)
RAW_DOT_ALLOWED = ("src/repro/core/numerics.py",)
SERVING_MODULES_PREFIX = "src/repro/serving/"
X64_ALLOWED = ("src/repro/compat.py",)
# modules that compute or apply pow2 scales — the bit-exactness surface
SCALE_MODULES = (
    "src/repro/kernels/common.py",
    "src/repro/kernels/tpmm/quantize.py",
    "src/repro/core/sd.py",
)


def _import_aliases(tree: ast.Module) -> dict:
    """name-in-module -> fully qualified dotted prefix."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.level:      # relative imports never alias jax/numpy
                continue
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _dotted(func: ast.AST, aliases: dict) -> str | None:
    """Resolve a call's func node to a fully qualified dotted name."""
    parts: list[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    base = aliases.get(node.id, node.id)
    return ".".join([base, *reversed(parts)])


def baseline_key(rule: str, relpath: str, qualname: str) -> str:
    return f"{rule}::{relpath}::{qualname}"


class _Visitor(ast.NodeVisitor):
    def __init__(self, relpath: str, aliases: dict, src_lines: list[str]):
        self.relpath = relpath
        self.aliases = aliases
        self.src_lines = src_lines
        self.stack: list[str] = []
        self.findings: list[tuple[str, int, str]] = []  # (rule, line, qual)

    def _qual(self) -> str:
        return ".".join(self.stack) or "<module>"

    def visit_FunctionDef(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_ClassDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func, self.aliases)
        if name:
            if (name in RAW_DOT_CALLS
                    and self.relpath not in RAW_DOT_ALLOWED):
                self.findings.append(("ast-raw-dot", node.lineno,
                                      self._qual()))
            if (name in TRANSCENDENTAL_CALLS
                    and self.relpath in SCALE_MODULES):
                self.findings.append(("ast-transcendental-scale",
                                      node.lineno, self._qual()))
            if (name in SERVING_CONTRACTION_CALLS
                    and self.relpath.startswith(SERVING_MODULES_PREFIX)):
                self.findings.append(("ast-serving-contraction",
                                      node.lineno, self._qual()))
            if (name.endswith("config.update")
                    and self.relpath not in X64_ALLOWED
                    and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and node.args[0].value == "jax_enable_x64"):
                self.findings.append(("ast-x64-config", node.lineno,
                                      self._qual()))
        self.generic_visit(node)


def lint_file(path: str, root: str | None = None
              ) -> list[tuple[str, str, int, str]]:
    """Lint one file; returns (rule, relpath, lineno, qualname) tuples
    (suppression not yet applied — `run` handles the baseline)."""
    root = root or _REPO_ROOT
    relpath = os.path.relpath(os.path.abspath(path), root).replace(os.sep, "/")
    with open(path, encoding="utf-8") as f:
        src = f.read()
    tree = ast.parse(src, filename=path)
    v = _Visitor(relpath, _import_aliases(tree), src.splitlines())
    v.visit(tree)
    return [(rule, relpath, line, qual) for rule, line, qual in v.findings]


def load_baseline(path: str | None = None) -> set[str]:
    path = path or DEFAULT_BASELINE_PATH
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        return set(json.load(f).get("suppressions", []))


def run(root: str | None = None, baseline: set[str] | str | None = None
        ) -> tuple[list[Violation], list[str], set[str]]:
    """Lint every .py under src/ of `root`.

    Returns (violations, raw_keys, unused_baseline): raw_keys is every
    finding's baseline key pre-suppression (what --write-baseline
    records); unused_baseline entries are stale suppressions worth
    pruning (reported, never fatal)."""
    root = os.path.abspath(root or _REPO_ROOT)
    if not isinstance(baseline, set):
        baseline = load_baseline(baseline)
    violations: list[Violation] = []
    raw_keys: list[str] = []
    used: set[str] = set()
    src_root = os.path.join(root, "src")
    for dirpath, dirnames, filenames in os.walk(src_root):
        dirnames.sort()
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            for rule, relpath, line, qual in lint_file(
                    os.path.join(dirpath, fn), root):
                key = baseline_key(rule, relpath, qual)
                raw_keys.append(key)
                if key in baseline:
                    used.add(key)
                    continue
                violations.append(Violation(
                    rule, f"{relpath}:{line}",
                    f"in {qual} (suppress with baseline key {key!r} "
                    "only for grandfathered sites)"))
    return violations, raw_keys, baseline - used
