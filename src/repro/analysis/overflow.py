"""Engine 1 core: symbolic worst-case magnitude propagation (Eq. 8).

`checked_schedule` guards kernel entry with the coarse bound
max T(j) + 3 <= 31 — a spot check on the schedule's plateau. This
module *proves* the property it stands for: an interval-arithmetic walk
of the exact int32 recurrence in kernels/online_mul/kernel.py
(mul_digit_loop), mirroring it operation for operation — the arriving-
digit register writes, the SELECTOR mux term, the arithmetic-shift
truncations (whose toward--inf rounding can GROW a negative magnitude
by 2^drop - 1: that slack is modeled, not ignored), the V = 2W + append
update, and the selection-cased residual after the z_j * 2^S
subtraction — propagating the worst-case magnitude of every
architectural quantity across all n + delta steps of the Fig. 7
schedule. The prover is strictly finer than the runtime guard, so
everything `fits_int32` accepts must come out proven here (one
direction; the prover also rejects configs the guard rejects, e.g. the
untruncated n = 32 schedule whose S = 35 puts the first live register
write at 2^34).

The online adder tree needs no interval walk: its digits provably never
leave {-2..2}, shown by exhaustive enumeration of the 2-digit-window
recurrence over all (e_k, e_{k+1}, e_{k+2}) triples — the shared middle
digit is what makes w_k = +-1 with t_{k+1} of the same sign impossible.
What k_tile actually constrains is the *stream length* into the exact
decode, checked against the per-width window for every k_tile in the
autotuner's legal range.
"""
from __future__ import annotations

import itertools
from typing import Iterable

from repro.core.precision import OnlinePrecision
from repro.kernels.common import decode_policy, fits_int32
from repro.kernels.online_dot.ref import tree_levels
from repro.kernels.online_dot.tuning import decode_window, max_k_tile
from repro.kernels.online_mul.ref import schedule_arrays

from .contracts import Violation

__all__ = ["prove_schedule", "adder_tree_digit_bound", "check_schedule",
           "check_decode_windows", "run"]

INT32_MAX = 2**31 - 1


_G_ZERO = 62   # granule sentinel for an exactly-zero quantity


def _ival(mag: int, granule: int) -> tuple[int, int]:
    """Interval element: value v satisfies |v| <= mag AND v is a
    multiple of 2^granule. Tracking the granule is what keeps the walk
    tight: floor_at is *exact* on values already aligned to its drop
    (the common case — register updates add aligned weights), so slack
    only enters where the real datapath truncates real bits."""
    return (mag, granule if mag else _G_ZERO)


def _add(a, b):
    return _ival(a[0] + b[0], min(a[1], b[1]))


def _shr(a, k: int):
    """Arithmetic shift right by k (floor): exact when aligned, else
    the floor of a negative value rounds away from zero by < 1."""
    m, g = a
    if g >= k:
        return _ival(m >> k, g - k)
    return _ival(-((-m) >> k), 0)   # ceil(m / 2^k)


def _floor_at(a, drop: int):
    """The kernel's floor_at: truncate below 2^drop toward -inf. Exact
    on aligned values; otherwise the magnitude bound rounds up to the
    next multiple of 2^drop (v = -m floors to -ceil(m/2^drop)*2^drop)."""
    m, g = a
    if drop <= 0 or g >= drop:
        return a
    return _ival(-((-m) >> drop) << drop, drop)


def prove_schedule(cfg: OnlinePrecision) -> tuple[int, str]:
    """Worst-case bit width any architectural quantity of the int32
    digit recurrence reaches under `cfg`'s T(j) schedule.

    Returns (bits, detail): bits is the width needed (<= 31 means every
    intermediate provably fits int32, sign bit excluded); detail names
    the widest quantity and the step it peaks at. The walk is a sound
    over-approximation: digits range over their full {-1,0,1} domain
    independently and the z_j selection is a case union, so any real
    digit pattern's trajectory lies inside the tracked intervals.
    """
    sched = [int(v) for v in schedule_arrays(cfg)]
    S = max(sched)
    n, delta, t = cfg.n, cfg.delta, cfg.t
    X = Y = W = _ival(0, _G_ZERO)
    peak, peak_detail = 0, "all-zero datapath"

    def note(a, what: str, step: int):
        nonlocal peak, peak_detail
        if a[0] > peak:
            peak = a[0]
            peak_detail = f"{what} at step {step} (j={step - delta})"

    for s in range(n + delta):
        j = s - delta
        T = sched[s]
        q = s + 1                       # arriving digit position
        dig = 1 if 1 <= q <= n else 0   # |x_q|, |y_q| <= 1 while in range
        drop = max(S - T, 0)
        live = q <= min(T, S) and dig
        wq = _ival(1 << max(S - q, 0), max(S - q, 0)) if live else _ival(0, 0)
        note(wq, "digit weight wq", s)
        Yf = _add(Y, wq)                # Y + yn*wq, |yn| <= 1
        note(Yf, "Y register after append", s)
        term = _add(X, Yf)              # X*yn + Yf*xn, digit mul <= identity
        note(term, "SELECTOR mux term", s)
        append = _floor_at(_shr(term, delta), drop)
        Xf = _add(X, wq)
        note(Xf, "X register after append", s)
        X = _floor_at(Xf, drop)
        Y = _floor_at(Yf, drop)
        V = _add(_ival(2 * W[0], W[1] + 1), append)
        note(V, "residual V = 2W + append", s)
        if j >= 0:
            note(_ival(1 << S, S), "output digit weight 2^S", s)
            # selection cases on vq = V >> (S - t): z_j in {-1,0,1}.
            # z_j = 0 only while |V| < thr; the +-1 subtraction leaves
            # |V - 2^S| <= max(V_max - 2^S, 2^S - thr) when reachable.
            thr = 2 << (S - t)
            m = min(V[0], thr)
            if V[0] >= thr:
                m = max(m, V[0] - (1 << S), (1 << S) - thr)
            w_pre = _ival(m, min(V[1], S))
        else:
            w_pre = V
        W = _floor_at(w_pre, drop)
        note(W, "residual W after truncation", s)
    return peak.bit_length(), f"{peak_detail}: |.| <= {peak} " \
                              f"({peak.bit_length()} bits; S={S})"


def adder_tree_digit_bound() -> int:
    """Max |output digit| of the online adder-tree recurrence, proven by
    exhaustive enumeration of its 2-digit window over every consistent
    (e_k, e_{k+1}, e_{k+2}) triple with e in [-2, 2] (pairwise sums of
    SD digits). Must be 1: then level outputs are again SD digits, the
    per-level bound holds inductively down the whole tree, and no tree
    value ever stresses int32."""
    def transfer(e, en):
        if e >= 2 or (e == 1 and en >= 0):
            return 1
        if e <= -2 or (e == -1 and en < 0):
            return -1
        return 0

    worst = 0
    rng = range(-2, 3)
    for ek, e1, e2 in itertools.product(rng, rng, rng):
        w_k = ek - 2 * transfer(ek, e1)
        out = w_k + transfer(e1, e2)
        worst = max(worst, abs(out))
    return worst


def check_schedule(cfg: OnlinePrecision, *, where: str) -> list[Violation]:
    """int32-overflow contract for one precision config."""
    bits, detail = prove_schedule(cfg)
    if bits <= 31:
        return []
    extra = ("" if not fits_int32(cfg) else
             " — and the runtime fits_int32 guard WRONGLY accepts it")
    return [Violation("int32-overflow", where,
                      f"recurrence needs {bits} bits: {detail}{extra}")]


def check_decode_windows(n_bits: int, *, where: str) -> list[Violation]:
    """decode-window contract over the autotuner's legal k_tile range
    (every power of two up to max_k_tile), plus the tree-digit lemma
    that makes stream length the only k_tile-dependent hazard."""
    out: list[Violation] = []
    bound = adder_tree_digit_bound()
    if bound > 1:
        out.append(Violation(
            "int32-overflow", where,
            f"adder-tree output digits reach |{bound}| > 1: the "
            "per-level SD-digit induction is broken"))
    kt, window = 1, decode_window(n_bits)
    while kt <= max_k_tile(n_bits):
        m = n_bits + 2 * tree_levels(kt)
        try:
            decode_policy(m)
            legal = m <= window
        except ValueError:
            legal = False
        if not legal:
            out.append(Violation(
                "decode-window", f"{where} k_tile={kt}",
                f"stream length {m} = {n_bits} + 2*ceil(log2 {kt}) "
                f"exceeds this width's exact window of {window} digits"))
        kt *= 2
    return out


def run(widths: Iterable[int] | None = None) -> list[Violation]:
    """Prove the overflow/decode contracts for every registered width,
    including each width's truncated olm{n}t{p} tiers (their schedules
    are the p-digit arrays; the proofs run at p under the family
    label)."""
    from repro.configs.olm_array import MATMUL_MODES, TRUNCATED_SPECS
    widths = tuple(sorted(widths if widths is not None else MATMUL_MODES))
    out: list[Violation] = []
    for n in widths:
        cfg = OnlinePrecision(n=n)
        out.extend(check_schedule(cfg, where=f"schedule/olm{n}"))
        out.extend(check_decode_windows(n, where=f"decode/olm{n}"))
        for nn, p in TRUNCATED_SPECS:
            if nn != n:
                continue
            out.extend(check_schedule(OnlinePrecision(n=p),
                                      where=f"schedule/olm{n}t{p}"))
            out.extend(check_decode_windows(p, where=f"decode/olm{n}t{p}"))
    return out
