"""olmlint — static kernel-contract & numerics analyzer.

Two engines over the repo's correctness story (README "Kernel
contracts" maps each contract to the paper invariant it enforces):

  Engine 1 (kernel lint): abstract jaxpr tracing of every registered
    Pallas kernel body at every MATMUL_MODES width x representative
    tiling bucket, under both x64 settings (jaxpr_lint); a symbolic
    worst-case magnitude proof of int32 non-overflow through the Fig. 7
    / Eq. 8 truncation schedule plus decode-window coverage of the
    autotuner's legal k_tile range (overflow); and a static VMEM
    footprint model from the kernels' own block-shape tables against
    the width-aware lane budget (vmem).

  Engine 2 (AST lint): repo architecture rules over src/ with a
    committed suppression baseline (ast_lint).

CLI: tools/olmlint.py (`make lint`, `make lint-kernels`). CI runs both
engines on both jax matrix versions alongside check-bench.
"""
from __future__ import annotations

from typing import Iterable

from . import ast_lint, jaxpr_lint, overflow, vmem
from .contracts import CONTRACTS, Violation
from .registry import KernelCase, iter_cases

__all__ = ["CONTRACTS", "Violation", "KernelCase", "iter_cases",
           "run_kernel_lint", "run_ast_lint", "run_all"]


def run_kernel_lint(widths: Iterable[int] | None = None,
                    tuning_path: str | None = None) -> list[Violation]:
    """Engine 1: jaxpr contracts + overflow proof + VMEM model."""
    out: list[Violation] = []
    out.extend(jaxpr_lint.run(widths))
    out.extend(overflow.run(widths))
    out.extend(vmem.run(widths, tuning_path))
    return out


def run_ast_lint(root: str | None = None,
                 baseline: set[str] | str | None = None
                 ) -> tuple[list[Violation], list[str], set[str]]:
    """Engine 2: AST repo rules. Returns (violations, raw keys, unused
    baseline entries) — see ast_lint.run."""
    return ast_lint.run(root, baseline)


def run_all(widths: Iterable[int] | None = None,
            root: str | None = None,
            baseline: set[str] | str | None = None) -> list[Violation]:
    """Both engines; the CLI's default."""
    violations = run_kernel_lint(widths)
    ast_violations, _, _ = run_ast_lint(root, baseline)
    return violations + ast_violations
