"""Registered-kernel enumeration for the olmlint jaxpr engine.

One KernelCase per (pure kernel body, width, representative tiling
bucket). The bodies are the exact functions the shipped pallas_call
kernels execute — tile_update / fused_tile_update (both matmul paths),
lane_tree (the batched dot kernel), mul_digit_loop (the online
multiplier), plane_accumulate (tpmm) — traced abstractly with
jax.make_jaxpr on ShapeDtypeStructs, so enumerating all of them costs
no FLOPs and no device memory.

Tiling buckets per width: the static configs/olm_array.MATMUL_TILING
default, the autotuner's GEMV heuristic (M=1 decode), and its large
training-GEMM heuristic — the three shapes the tuner actually serves —
deduplicated per width. New kernel families (e.g. the truncated
olm{n}t{p} modes on the ROADMAP) register here to come under the same
static contracts.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.olm_array import (MATMUL_MODES, MATMUL_TILING,
                                     TRUNCATED_SPECS)
from repro.core.precision import OnlinePrecision
from repro.kernels.common import checked_schedule, decode_policy
from repro.kernels.online_dot.kernel import lane_tree
from repro.kernels.online_dot.matmul_kernel import (fused_tile_update,
                                                    tile_update)
from repro.kernels.online_dot.ref import tree_levels
from repro.kernels.online_dot.tuning import heuristic_tiling, pinned_k_tile
from repro.kernels.online_mul.kernel import mul_digit_loop
from repro.kernels.tpmm.kernel import plane_accumulate
from repro.kernels.tpmm.ref import kept_levels

__all__ = ["KernelCase", "representative_tilings", "iter_cases"]

# Representative lane-count for the standalone (non-matmul) kernels: a
# small block keeps the traced jaxprs small without changing which
# primitives appear (block size is a shape, not a code path).
_BLOCK_B = 8
_DOT_K = 16
# tpmm traces at its MXU-aligned default blocks.
_TPMM_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class KernelCase:
    """One abstract trace target: `trace()` returns the closed jaxpr of
    the kernel body under the ambient x64 setting; `out_dtypes` is what
    the body's outputs must carry (the kernel-accum-dtype contract);
    `tiling` (k_tile, block_m, block_n) is set for matmul cases so the
    VMEM engine can reuse the same enumeration."""

    name: str
    n_bits: int
    trace: Callable[[], jax.core.ClosedJaxpr]
    out_dtypes: Tuple[str, ...]
    tiling: Tuple[int, int, int] | None = None


def representative_tilings(n_bits: int) -> dict:
    """label -> (k_tile, block_m, block_n): the tiling buckets the
    matmul kernels actually run under for this width — the static
    default, the autotuner's GEMV and training-GEMM heuristics, and the
    shard-LOCAL shapes the shard_map front-end
    (kernels/online_dot/matmul_sharded.py) autotunes on when those same
    GEMMs are partitioned over an 8-way mesh axis (tiling="auto" runs
    get_tiling on the PER-DEVICE shard, so the served buckets differ
    from the global-shape ones and must be proved separately) —
    deduplicated (wide modes often collapse buckets)."""
    kt_static = pinned_k_tile(MATMUL_TILING["k_tile"], n_bits)
    buckets = {
        "static": (kt_static, MATMUL_TILING["block_m"],
                   MATMUL_TILING["block_n"]),
    }
    for label, (M, N, K) in (
            ("gemv", (1, 4096, 4096)),
            ("train", (8192, 4096, 4096)),
            # shard-local mates over an 8-device axis: the decode GEMV
            # N-sharded, the training GEMM M-sharded and K-sharded.
            ("shard8-gemv-n", (1, 512, 4096)),
            ("shard8-train-m", (1024, 4096, 4096)),
            ("shard8-train-k", (8192, 4096, 512))):
        t = heuristic_tiling(M, N, K, n_bits)
        tiling = (t.k_tile, t.block_m, t.block_n)
        if tiling not in buckets.values():
            buckets[label] = tiling
    return buckets


def _sched_aval(cfg: OnlinePrecision):
    return jax.ShapeDtypeStruct((cfg.n + cfg.delta,), jnp.int32)


def _matmul_statics(n_bits: int, kt: int) -> dict:
    """The static kwargs both matmul tile bodies take, exactly as the
    pallas_call front-end computes them."""
    cfg = OnlinePrecision(n=n_bits)
    _, S = checked_schedule(cfg)
    L = tree_levels(kt)
    return dict(n=n_bits, delta=cfg.delta, t=cfg.t, S=S, L=L,
                wide=decode_policy(n_bits + 2 * L) == "wide")


def iter_cases(widths: Tuple[int, ...] | None = None) -> list[KernelCase]:
    """Every registered Pallas kernel body x width x tiling bucket."""
    widths = tuple(sorted(widths if widths is not None else MATMUL_MODES))
    cases: list[KernelCase] = []
    i32 = jnp.int32
    f32 = jnp.float32
    for n in widths:
        cfg = OnlinePrecision(n=n)
        sched = _sched_aval(cfg)
        mul_kw = dict(n=n, delta=cfg.delta, t=cfg.t,
                      S=checked_schedule(cfg)[1])

        # online_mul: the batched digit recurrence (mul_digit_loop).
        dig2 = jax.ShapeDtypeStruct((_BLOCK_B, n), i32)
        cases.append(KernelCase(
            name=f"mul_digit_loop/olm{n}", n_bits=n,
            trace=functools.partial(
                jax.make_jaxpr(functools.partial(mul_digit_loop, **mul_kw)),
                dig2, dig2, sched),
            out_dtypes=("int32",)))

        # online_dot: K-lane multiplier + online adder tree (lane_tree).
        dig3 = jax.ShapeDtypeStruct((_BLOCK_B, _DOT_K, n), i32)
        cases.append(KernelCase(
            name=f"lane_tree/olm{n}/k{_DOT_K}", n_bits=n,
            trace=functools.partial(
                jax.make_jaxpr(functools.partial(lane_tree, **mul_kw)),
                dig3, dig3, sched),
            out_dtypes=("int32",)))

        # both matmul paths, per representative tiling bucket.
        for label, (kt, bm, bn) in representative_tilings(n).items():
            statics = _matmul_statics(n, kt)
            xd = jax.ShapeDtypeStruct((bm, kt, n), i32)
            wd = jax.ShapeDtypeStruct((bn, kt, n), i32)
            sx = jax.ShapeDtypeStruct((bm, 1), f32)
            sw = jax.ShapeDtypeStruct((bn, 1), f32)
            cases.append(KernelCase(
                name=f"matmul-host/olm{n}/{label}-k{kt}m{bm}n{bn}",
                n_bits=n,
                trace=functools.partial(
                    jax.make_jaxpr(functools.partial(tile_update, **statics)),
                    xd, sx, wd, sw, sched),
                out_dtypes=("float32",), tiling=(kt, bm, bn)))
            xt = jax.ShapeDtypeStruct((bm, kt), f32)
            wt = jax.ShapeDtypeStruct((bn, kt), f32)
            cases.append(KernelCase(
                name=f"matmul-fused/olm{n}/{label}-k{kt}m{bm}n{bn}",
                n_bits=n,
                trace=functools.partial(
                    jax.make_jaxpr(
                        functools.partial(fused_tile_update, **statics)),
                    xt, wt, sched),
                out_dtypes=("float32",), tiling=(kt, bm, bn)))

        # truncated tiers olm{n}t{p}: the same kernel bodies instanced
        # at p work digits. The schedule, buckets, and statics are the
        # p-digit ones (that IS the mode), but the cases register under
        # the family name so `make lint` proves every servable mode by
        # its own label.
        for nn, p in TRUNCATED_SPECS:
            if nn != n:
                continue
            tcfg = OnlinePrecision(n=p)
            tsched = _sched_aval(tcfg)
            tmul_kw = dict(n=p, delta=tcfg.delta, t=tcfg.t,
                           S=checked_schedule(tcfg)[1])
            tdig2 = jax.ShapeDtypeStruct((_BLOCK_B, p), i32)
            cases.append(KernelCase(
                name=f"mul_digit_loop/olm{n}t{p}", n_bits=p,
                trace=functools.partial(
                    jax.make_jaxpr(
                        functools.partial(mul_digit_loop, **tmul_kw)),
                    tdig2, tdig2, tsched),
                out_dtypes=("int32",)))
            for label, (kt, bm, bn) in representative_tilings(p).items():
                statics = _matmul_statics(p, kt)
                xd = jax.ShapeDtypeStruct((bm, kt, p), i32)
                wd = jax.ShapeDtypeStruct((bn, kt, p), i32)
                sx = jax.ShapeDtypeStruct((bm, 1), f32)
                sw = jax.ShapeDtypeStruct((bn, 1), f32)
                cases.append(KernelCase(
                    name=f"matmul-host/olm{n}t{p}/{label}-k{kt}m{bm}n{bn}",
                    n_bits=p,
                    trace=functools.partial(
                        jax.make_jaxpr(
                            functools.partial(tile_update, **statics)),
                        xd, sx, wd, sw, tsched),
                    out_dtypes=("float32",), tiling=(kt, bm, bn)))
                xt = jax.ShapeDtypeStruct((bm, kt), f32)
                wt = jax.ShapeDtypeStruct((bn, kt), f32)
                cases.append(KernelCase(
                    name=f"matmul-fused/olm{n}t{p}/{label}-k{kt}m{bm}n{bn}",
                    n_bits=p,
                    trace=functools.partial(
                        jax.make_jaxpr(
                            functools.partial(fused_tile_update, **statics)),
                        xt, wt, tsched),
                    out_dtypes=("float32",), tiling=(kt, bm, bn)))

        # tpmm: digit-plane matmul body at its supported widths (planes
        # are 4-bit; D = n/4 must be integral and <= 8).
        if n % 4 == 0 and n // 4 <= 8:
            D = n // 4
            lmax = kept_levels(n, 4)
            a = jax.ShapeDtypeStruct((D, _TPMM_BLOCK, _TPMM_BLOCK), jnp.int8)
            b = jax.ShapeDtypeStruct((D, _TPMM_BLOCK, _TPMM_BLOCK), jnp.int8)
            acc = jax.ShapeDtypeStruct((_TPMM_BLOCK, _TPMM_BLOCK), f32)
            cases.append(KernelCase(
                name=f"tpmm/plane_accumulate/n{n}", n_bits=n,
                trace=functools.partial(
                    jax.make_jaxpr(functools.partial(
                        plane_accumulate, n_planes=D, plane_bits=4,
                        lmax=lmax)),
                    a, b, acc),
                out_dtypes=("float32",)))
    return cases
