"""Engine 1 core: jaxpr contract checks on abstractly traced kernels.

Each registered kernel body (repro.analysis.registry) is traced with
jax.make_jaxpr under BOTH x64 settings (repro.compat.enable_x64 scope —
never a global config flip) and every equation, including those inside
scan/while/cond/pjit sub-jaxprs, is checked against the kernel-legality
contracts:

  kernel-no-int64           no 64-bit avals anywhere in the body. With
                            x64 off JAX canonicalizes int64 away, so the
                            x64-ON trace is the adversarial one: a
                            Python-int fori_loop bound or a stray
                            astype(int64) only shows there — precisely
                            the "works on CI leg A, breaks on leg B"
                            class this engine exists to kill.
  kernel-no-transcendental  no exp/exp2/log/pow/... primitives: pow2
                            scales and decode weights must be built by
                            exponent-field bitcast, never a libm call.
  kernel-no-1d-iota         1-D iota does not lower on TPU.
  kernel-accum-dtype        body outputs carry their declared dtypes.

Failures carry the offending equation (pretty-printed, truncated) so
`make lint-kernels` output points at the exact primitive.
"""
from __future__ import annotations

from typing import Iterable, Iterator, Sequence

from repro import compat

from .contracts import Violation
from .registry import KernelCase, iter_cases

__all__ = ["BANNED_DTYPES", "TRANSCENDENTAL_PRIMS", "iter_eqns",
           "check_jaxpr", "check_case", "run"]

BANNED_DTYPES = frozenset({"int64", "uint64", "float64", "complex128"})

# lax primitive names with data-dependent libm semantics. integer_pow is
# deliberately absent: x**2 lowers to it and it is exact multiplication.
TRANSCENDENTAL_PRIMS = frozenset({
    "exp", "exp2", "expm1", "log", "log2", "log1p", "pow", "sqrt", "rsqrt",
    "cbrt", "logistic", "tanh", "tan", "sin", "cos", "asin", "acos", "atan",
    "atan2", "sinh", "cosh", "asinh", "acosh", "atanh", "erf", "erfc",
    "erf_inv", "digamma", "lgamma",
})


def _sub_jaxprs(params: dict) -> Iterator:
    """Yield every (Closed)Jaxpr hiding in an eqn's params — scan/while
    bodies, cond branches, pjit/closed_call callees. Duck-typed (an
    object with .eqns is a Jaxpr, one with .jaxpr wraps one) so it works
    across the jax 0.4.x..latest core API moves."""
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for item in vs:
            if hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                yield item.jaxpr
            elif hasattr(item, "eqns"):
                yield item


def iter_eqns(jaxpr) -> Iterator:
    """All equations of `jaxpr`, depth-first through sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def _fmt_eqn(eqn) -> str:
    text = " ".join(str(eqn).split())
    return text if len(text) <= 180 else text[:177] + "..."


def check_jaxpr(closed, *, where: str,
                out_dtypes: Sequence[str] | None = None) -> list[Violation]:
    """Run the per-equation contracts over one closed jaxpr."""
    out: list[Violation] = []
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    for eqn in iter_eqns(jaxpr):
        prim = eqn.primitive.name
        for var in (*eqn.invars, *eqn.outvars):
            aval = getattr(var, "aval", None)
            dt = str(getattr(aval, "dtype", ""))
            if dt in BANNED_DTYPES:
                out.append(Violation(
                    "kernel-no-int64", where,
                    f"{dt} aval in eqn: {_fmt_eqn(eqn)}"))
                break
        if prim in TRANSCENDENTAL_PRIMS:
            out.append(Violation(
                "kernel-no-transcendental", where,
                f"transcendental primitive '{prim}': {_fmt_eqn(eqn)}"))
        if prim == "iota" and len(eqn.params.get("shape", ())) == 1:
            out.append(Violation(
                "kernel-no-1d-iota", where,
                f"1-D iota (does not lower on TPU): {_fmt_eqn(eqn)}"))
    if out_dtypes is not None:
        got = tuple(str(v.aval.dtype) for v in jaxpr.outvars)
        if got != tuple(out_dtypes):
            out.append(Violation(
                "kernel-accum-dtype", where,
                f"body outputs carry {got}, declared {tuple(out_dtypes)}"))
    return out


def check_case(case: KernelCase) -> list[Violation]:
    """Trace one kernel case under both x64 settings and check it. The
    trace itself failing is reported as a violation rather than raised:
    a kernel that cannot even trace under some x64 setting has broken
    the x64-independence contract."""
    out: list[Violation] = []
    for x64 in (False, True):
        leg = f"{case.name} [x64={'on' if x64 else 'off'}]"
        try:
            with compat.enable_x64(x64):
                closed = case.trace()
        except Exception as e:  # noqa: BLE001 — any trace error is a finding
            out.append(Violation(
                "kernel-no-int64", leg,
                f"abstract trace failed under this x64 setting: {e}"))
            continue
        out.extend(check_jaxpr(closed, where=leg,
                               out_dtypes=case.out_dtypes))
    return out


def run(widths: Iterable[int] | None = None,
        cases: Sequence[KernelCase] | None = None) -> list[Violation]:
    """Jaxpr-lint every registered kernel case (or the given ones)."""
    if cases is None:
        cases = iter_cases(tuple(widths) if widths is not None else None)
    out: list[Violation] = []
    for case in cases:
        out.extend(check_case(case))
    return out
