"""Engine 1 core: static VMEM footprint model vs the width-aware budget.

The footprint of one grid step is computed from the SAME block-shape
tables the pallas_calls build their BlockSpecs from (each kernel
module's *_block_shapes function — one source, so kernel and analyzer
cannot disagree), plus the in-kernel lane working set the matmul tile
bodies materialize on top of their blocks: the broadcast digit grids
(2 x (block_m*block_n, k_tile, n) int32), the product streams, and the
dot stream — the quantities `tuning.lane_budget` exists to bound.

Two checks per matmul case:

  vmem-budget (lane)  block_m * block_n * k_tile <= lane_budget(n_bits)
                      — the exact inequality heuristic_tiling and
                      _candidates spend, imported from tuning (the ONE
                      budget function; Issue 6 satellite 1).
  vmem-budget (bytes) blocks + lane working set <= VMEM_BYTES (~16 MB).

The committed tuning cache (results/tuning.json) is validated entry by
entry against the same two checks, so a stale or hand-edited cache that
would steer the kernel over budget fails lint before it fails on a TPU.
"""
from __future__ import annotations

import json
import os
from typing import Iterable

import numpy as np

from repro.kernels.online_dot.kernel import dot_block_shapes
from repro.kernels.online_dot.matmul_kernel import (fused_matmul_block_shapes,
                                                    matmul_block_shapes)
from repro.kernels.online_dot.ref import tree_levels
from repro.kernels.online_dot.tuning import (DEFAULT_CACHE_PATH, lane_budget,
                                             max_k_tile)
from repro.kernels.online_mul.kernel import mul_block_shapes
from repro.kernels.tpmm.kernel import tpmm_block_shapes

from .contracts import Violation
from .registry import representative_tilings

__all__ = ["VMEM_BYTES", "block_bytes", "matmul_working_set_bytes",
           "check_matmul_tiling", "check_tuning_cache", "run"]

# Per-core VMEM capacity the footprint model checks against
# (TPUv4/v5-class cores carry 16 MB of VMEM).
VMEM_BYTES = 16 * 2**20

_DELTA = 3   # OnlinePrecision default online delay


def block_bytes(blocks: dict) -> int:
    """Total bytes of one grid step's VMEM-resident blocks, from a
    *_block_shapes table (name -> (shape, dtype))."""
    return sum(int(np.prod(shape)) * np.dtype(dtype).itemsize
               for shape, dtype in blocks.values())


def matmul_working_set_bytes(n_bits: int, kt: int, bm: int, bn: int) -> int:
    """Bytes the matmul tile body materializes beyond its input blocks:
    the two broadcast digit grids fanned out to the (bm*bn) lane batch,
    the per-lane product streams, and the decoded dot stream — int32
    everywhere (mirrors tile_update -> lane_tree)."""
    lanes = bm * bn
    m_out = n_bits + 2 * tree_levels(kt)
    grids = 2 * lanes * kt * n_bits          # xg, wg broadcast grids
    prods = lanes * kt * n_bits              # mul_digit_loop output
    stream = lanes * m_out                   # adder-tree dot stream
    return 4 * (grids + prods + stream)


def check_matmul_tiling(n_bits: int, kt: int, bm: int, bn: int,
                        *, where: str) -> list[Violation]:
    """Both vmem-budget checks for one matmul tiling at one width."""
    out: list[Violation] = []
    lanes, budget = bm * bn * kt, lane_budget(n_bits)
    if lanes > budget:
        out.append(Violation(
            "vmem-budget", where,
            f"lane batch block_m*block_n*k_tile = {bm}*{bn}*{kt} = "
            f"{lanes} exceeds lane_budget({n_bits}) = {budget}"))
    if kt > max_k_tile(n_bits):
        out.append(Violation(
            "decode-window", where,
            f"k_tile {kt} exceeds max_k_tile({n_bits}) = "
            f"{max_k_tile(n_bits)} — the stream would leave the exact "
            "decode window"))
    for label, blocks in (
            ("host", matmul_block_shapes(n=n_bits, delta=_DELTA, kt=kt,
                                         bm=bm, bn=bn)),
            ("fused", fused_matmul_block_shapes(n=n_bits, delta=_DELTA,
                                                kt=kt, bm=bm, bn=bn))):
        total = (block_bytes(blocks)
                 + matmul_working_set_bytes(n_bits, kt, bm, bn))
        if total > VMEM_BYTES:
            out.append(Violation(
                "vmem-budget", f"{where} [{label} path]",
                f"static footprint {total} B (blocks "
                f"{block_bytes(blocks)} B + lane working set) exceeds "
                f"VMEM capacity {VMEM_BYTES} B"))
    return out


def _check_simple_kernels(n_bits: int) -> list[Violation]:
    """Footprint-only checks for the non-matmul kernel layouts at their
    shipped default block sizes."""
    out: list[Violation] = []
    fixed = {
        f"online_mul/olm{n_bits}": mul_block_shapes(
            n=n_bits, delta=_DELTA, block_b=1024),
        f"online_dot/olm{n_bits}": dot_block_shapes(
            n=n_bits, delta=_DELTA, K=16, block_b=8),
    }
    if n_bits % 4 == 0 and n_bits // 4 <= 8:
        fixed[f"tpmm/n{n_bits}"] = tpmm_block_shapes(
            n_planes=n_bits // 4, block_m=128, block_n=128, block_k=128)
    for where, blocks in fixed.items():
        total = block_bytes(blocks)
        if total > VMEM_BYTES:
            out.append(Violation(
                "vmem-budget", where,
                f"static block footprint {total} B exceeds VMEM "
                f"capacity {VMEM_BYTES} B"))
    return out


def check_tuning_cache(path: str | None = None) -> list[Violation]:
    """Validate every committed tuning-cache entry against the same
    budget the analyzer applies to the registered tilings."""
    path = path or DEFAULT_CACHE_PATH
    if not os.path.exists(path):
        return []
    with open(path) as f:
        entries = json.load(f).get("entries", {})
    out: list[Violation] = []
    for key in sorted(entries):
        e = entries[key]
        # Truncated-mode entries carry "trunc": the work digits the
        # kernel (and therefore the budget) actually runs at.
        work = int(e.get("trunc") or e["n_bits"])
        out.extend(check_matmul_tiling(
            work, int(e["k_tile"]), int(e["block_m"]),
            int(e["block_n"]),
            where=f"tuning-cache {os.path.basename(path)}::{key}"))
    return out


def run(widths: Iterable[int] | None = None,
        tuning_path: str | None = None) -> list[Violation]:
    """VMEM-lint every registered width's representative tilings, the
    fixed-layout kernels, and the committed tuning cache."""
    from repro.configs.olm_array import MATMUL_MODES, TRUNCATED_SPECS
    widths = tuple(sorted(widths if widths is not None else MATMUL_MODES))
    out: list[Violation] = []
    for n in widths:
        out.extend(_check_simple_kernels(n))
        for label, (kt, bm, bn) in representative_tilings(n).items():
            out.extend(check_matmul_tiling(
                n, kt, bm, bn, where=f"matmul/olm{n}/{label}"))
        for nn, p in TRUNCATED_SPECS:
            if nn != n:
                continue
            for label, (kt, bm, bn) in representative_tilings(p).items():
                out.extend(check_matmul_tiling(
                    p, kt, bm, bn, where=f"matmul/olm{n}t{p}/{label}"))
    out.extend(check_tuning_cache(tuning_path))
    return out
