"""Contract registry and the Violation record every olmlint engine emits.

A *contract* is one statically checkable invariant the paper's
correctness story rests on. Each has a stable id (the key below) that
failures are reported under — tests assert on these ids, the CLI prints
them, and the suppression baseline keys off them — plus a one-line
statement of the invariant and where it comes from (paper Eq. 8, the
exact-decode windows, TPU lowering rules, or repo architecture).
"""
from __future__ import annotations

import dataclasses

__all__ = ["Violation", "CONTRACTS"]

CONTRACTS = {
    # -- Engine 1: jaxpr contract checker (repro.analysis.jaxpr_lint) --
    "kernel-no-int64": (
        "Pallas kernel bodies must not contain int64/uint64/float64 "
        "primitives or x64-dependent dtypes: the TPU datapath is 32-bit "
        "and results must be bit-identical across x64 settings."),
    "kernel-no-transcendental": (
        "No transcendental primitives (exp/exp2/log/log2/pow/...) inside "
        "kernel bodies: the pow2-scale path must stay bitcast-exact — a "
        "backend's ulp wobble on exp2 breaks host/kernel bit-identity."),
    "kernel-no-1d-iota": (
        "No 1-D iota inside kernel bodies: it does not lower on TPU; use "
        "lax.broadcasted_iota with a >= 2-D shape."),
    "kernel-accum-dtype": (
        "Kernel outputs/accumulators must carry the declared dtype "
        "(int32 digit streams, float32 matmul accumulators) — a widened "
        "or narrowed accumulator silently changes numerics."),
    # -- Engine 1: symbolic overflow prover (repro.analysis.overflow) --
    "int32-overflow": (
        "Worst-case magnitude propagation through the Fig. 7 truncation "
        "schedule (paper Eq. 8: p = ceil((2n+delta+t)/3)) must prove "
        "every architectural quantity of the digit recurrence fits int32 "
        "for each (n_bits, k_tile) in the autotuner's legal range."),
    "decode-window": (
        "Dot-stream length n + 2*ceil(log2 k_tile) must stay inside the "
        "width's exact decode window (24 digits plain-f32, 48 wide "
        "two-limb) — past it the decode silently rounds and the "
        "three-path bit-identity breaks."),
    # -- Engine 1: static VMEM footprint model (repro.analysis.vmem) --
    "vmem-budget": (
        "The per-grid-step VMEM footprint from the kernel's BlockSpecs "
        "plus the in-kernel lane working set must respect the width-aware "
        "lane budget (tuning.lane_budget) and the ~16 MB VMEM capacity."),
    # -- Engine 2: AST repo lint (repro.analysis.ast_lint) --
    "ast-raw-dot": (
        "No raw jnp.dot / lax.dot_general outside core/numerics.py: "
        "every contraction routes through DotEngine so mode dispatch and "
        "the olm bit-identity guarantees cannot be bypassed."),
    "ast-x64-config": (
        "No jax.config.update('jax_enable_x64', ...) outside compat.py: "
        "x64 is scoped via repro.compat.enable_x64, never flipped "
        "globally — global flips leak into other tests/kernels."),
    "ast-transcendental-scale": (
        "No math.log2 / jnp.exp2 / jnp.log2 / pow in scale-computation "
        "modules: pow2 scales are built by exponent-field bitcast so "
        "they are exact powers of two on every backend."),
}


@dataclasses.dataclass(frozen=True)
class Violation:
    """One contract failure: the contract id, where it was found (kernel
    case name or file:line), and the offending evidence (jaxpr eqn, AST
    source line, or the numbers that broke the bound)."""

    contract: str
    where: str
    detail: str

    def __str__(self) -> str:
        text = CONTRACTS.get(self.contract, "(unknown contract)")
        return (f"[{self.contract}] {self.where}\n"
                f"    {self.detail}\n"
                f"    contract: {text}")
