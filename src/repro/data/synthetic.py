"""Deterministic synthetic token pipeline (host-sharded, restartable).

Generates a structured token stream (a mixture of Zipfian unigrams and
repeated n-gram motifs so models have something learnable) with:
  * determinism: stream state is (seed, step) — restoring a checkpoint at
    step k reproduces the exact batch k+1, with no data-state file needed;
  * host sharding: each process generates only its slice of the global
    batch (process_index/process_count);
  * frontend stubs: per-batch frame/patch embeddings for encdec/vlm.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig

__all__ = ["SyntheticLMDataset", "make_batches"]


@dataclasses.dataclass
class SyntheticLMDataset:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    process_index: int = 0
    process_count: int = 1
    motif_len: int = 16
    n_motifs: int = 64

    def __post_init__(self):
        if self.global_batch % self.process_count:
            raise ValueError("global_batch must divide across processes")
        self.local_batch = self.global_batch // self.process_count
        base = np.random.default_rng(self.seed)
        v = self.cfg.vocab_size
        # shared motif table (same on every host)
        self.motifs = base.integers(0, v, size=(self.n_motifs, self.motif_len))
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.process_index)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        B, S, v = self.local_batch, self.seq_len, self.cfg.vocab_size
        toks = rng.choice(v, size=(B, S), p=self.unigram).astype(np.int32)
        # overwrite random spans with motifs (learnable structure)
        n_spans = max(1, S // (4 * self.motif_len))
        for b in range(B):
            for _ in range(n_spans):
                m = rng.integers(0, self.n_motifs)
                at = rng.integers(0, max(S - self.motif_len, 1))
                toks[b, at:at + self.motif_len] = self.motifs[m]
        out: Dict[str, np.ndarray] = {"tokens": toks}
        if self.cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (B, self.cfg.n_frontend_tokens, self.cfg.d_model)
            ).astype(np.float32)
        if self.cfg.family == "vlm":
            out["patches"] = rng.standard_normal(
                (B, self.cfg.n_frontend_tokens, self.cfg.d_model)
            ).astype(np.float32)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def make_batches(cfg: ModelConfig, *, global_batch: int, seq_len: int,
                 seed: int = 0, start_step: int = 0):
    return SyntheticLMDataset(cfg, global_batch, seq_len, seed).iterate(start_step)
