from .synthetic import SyntheticLMDataset, make_batches
