"""Fault-tolerant checkpointing: atomic, sharded, keep-K, elastic restore.

Layout:
  <dir>/step_000100.tmp/...   (written first)
  <dir>/step_000100/          (atomic rename on completion)
      manifest.json           tree structure, shapes, dtypes, step
      shard_<i>.npz           leaf arrays (flattened tree order)

Properties:
  * atomicity — a crash mid-write never corrupts the latest checkpoint
    (readers only ever see fully renamed directories);
  * keep-K garbage collection;
  * async save (background thread) so the train loop is not blocked;
  * ELASTIC restore — arrays are saved unsharded (gathered) with the tree
    manifest, so a restore onto a different mesh shape just reshards via
    jax.device_put with the new sharding tree (tested in
    tests/test_checkpoint.py with changed mesh sizes);
  * data-pipeline state is implicit: the synthetic pipeline is keyed by
    (seed, step), so restoring `step` resumes the exact stream.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ----------------- save -----------------
    def save(self, step: int, tree: Any, *, block: bool = False) -> None:
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]
        if self._thread is not None:
            self._thread.join()  # one in-flight save at a time
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, treedef))
            self._thread.start()
        else:
            self._write(step, host_leaves, treedef)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, leaves, treedef) -> None:
        name = f"step_{step:08d}"
        tmp = self.dir / (name + ".tmp")
        final = self.dir / name
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "leaves": [{"shape": list(l.shape), "dtype": str(l.dtype)}
                       for l in leaves],
        }
        np.savez(tmp / "shard_0.npz",
                 **{f"leaf_{i}": l for i, l in enumerate(leaves)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ----------------- restore -----------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not p.is_dir():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `tree_like`. If `shardings` (a
        pytree of NamedSharding, possibly for a NEW mesh) is given, leaves
        are device_put with it — elastic re-sharding on load."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        data = np.load(path / "shard_0.npz")
        leaves, treedef = _flatten(tree_like)
        n = json.loads((path / "manifest.json").read_text())["n_leaves"]
        if n != len(leaves):
            raise ValueError(
                f"checkpoint has {n} leaves, target structure has {len(leaves)}")
        restored = [data[f"leaf_{i}"] for i in range(n)]
        if shardings is not None:
            sh_leaves = treedef.flatten_up_to(shardings)
            restored = [jax.device_put(r, s) if s is not None else r
                        for r, s in zip(restored, sh_leaves)]
        return jax.tree_util.tree_unflatten(treedef, restored)
