from .manager import CheckpointManager
