"""Custom compute kernels for the paper's hot spots.

Three families, each `kernel.py` (Pallas) + `ref.py` (jnp oracle) +
`ops.py` (dispatch), sharing the int32-fit / padding / quantize / decode
plumbing in `common.py`:

  online_mul — batched radix-2 online-multiplier digit recurrence
  online_dot — fused inner-product array: K multiplier lanes feeding a
               digit-serial online adder tree (the paper's target
               workload), plus `matmul.py`, the float-matmul front-end
               that K-tiles, signed-digit-quantizes and stream-decodes
               model GEMM tiles through the fused kernel
  tpmm       — truncated digit-plane matmul (the Eq. 8 truncation law
               transposed to MXU plane products)

All of them are reachable as model numerics through one dispatch
surface: `core.numerics.DotEngine` registers `tpmm{8,16}` (plane-pair
path) and `olm{8,16}` (fused-array path) alongside `native`, so every
transformer / MoE / recurrent matmul can select any family per layer.
"""
