"""Custom compute kernels for the paper's hot spots.

Three families, each `kernel.py` (Pallas) + `ref.py` (jnp oracle) +
`ops.py` (dispatch), sharing the int32-fit / padding / digit-decoding
helpers in `common.py`:

  online_mul — batched radix-2 online-multiplier digit recurrence
  online_dot — fused inner-product array: K multiplier lanes feeding a
               digit-serial online adder tree (the paper's target workload)
  tpmm       — truncated digit-plane matmul (the Eq. 8 truncation law
               transposed to MXU plane products)
"""
