"""Pure-jnp oracle for the batched online multiplier kernel.

Vectorized (batch) digit recurrence in int64, bit-identical to the exact
Python reference core.online_mul.online_multiply (property-tested). This is
the `ref.py` oracle that the Pallas kernel is allclose-asserted against
across shape/dtype sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.online_mul import working_precision
from repro.core.precision import OnlinePrecision

__all__ = ["schedule_arrays", "online_mul_batch_ref"]


def schedule_arrays(cfg: OnlinePrecision) -> np.ndarray:
    """Static T(j) schedule for j = -delta .. n-1, as an (n+delta,) array."""
    return np.array(
        [working_precision(cfg, j) for j in range(-cfg.delta, cfg.n)],
        dtype=np.int32,
    )


@functools.partial(jax.jit, static_argnames=("n", "delta", "t", "truncated",
                                             "tail_gating", "tail_guard"))
def online_mul_batch_ref(
    x_digits: jax.Array,  # (B, n) int32 digits in {-1,0,1}
    y_digits: jax.Array,  # (B, n)
    *,
    n: int,
    delta: int = 3,
    t: int = 2,
    truncated: bool = True,
    tail_gating: bool = True,
    tail_guard: int = 2,
) -> tuple[jax.Array, jax.Array]:
    """Batched online multiplication.

    Returns:
      z_digits: (B, n) int32 output SD digits.
      z_int:    (B,)  int64 product scaled by 2^n.
    """
    cfg = OnlinePrecision(n=n, delta=delta, t=t, truncated=truncated,
                          tail_gating=tail_gating, tail_guard=tail_guard)
    F = n + delta
    if F + 3 > 31 and jax.dtypes.canonicalize_dtype(jnp.int64) != jnp.int64:
        raise ValueError(
            f"online_mul_batch_ref with n={n} needs int64 (F+3={F+3} bits); "
            "enable x64 (repro.compat.enable_x64) or use the Pallas "
            "kernel, whose Eq.8-truncated datapath fits int32")
    sched = jnp.asarray(schedule_arrays(cfg))  # (n+delta,)
    B = x_digits.shape[0]
    xd = x_digits.astype(jnp.int64)
    yd = y_digits.astype(jnp.int64)

    def floor_at(v, T):
        drop = jnp.maximum(F - T, 0).astype(jnp.int64)
        return (v >> drop) << drop

    def body(s, carry):
        X, Y, W, Z, zout = carry
        j = s - delta
        T = sched[s].astype(jnp.int64)
        q = j + 1 + delta  # arriving digit position (1-indexed)
        in_range = jnp.logical_and(q >= 1, q <= n)
        col = jnp.clip(q - 1, 0, n - 1)
        xn = jnp.where(in_range, jax.lax.dynamic_index_in_dim(
            xd, col, axis=1, keepdims=False), 0)
        yn = jnp.where(in_range, jax.lax.dynamic_index_in_dim(
            yd, col, axis=1, keepdims=False), 0)
        # Register-slice gating: the arriving digit's own bit is stored only
        # while its slice is live (q <= T); it always drives the muxes.
        wq = jnp.where(
            jnp.asarray(q, jnp.int64) <= T,
            jnp.int64(1) << jnp.maximum(F - q, 0).astype(jnp.int64),
            jnp.int64(0),
        )
        Yf = Y + yn * wq
        term = X * yn + Yf * xn
        append = floor_at(term >> delta, T)
        Xf = X + xn * wq
        Xn = floor_at(Xf, T)
        Yn = floor_at(Yf, T)
        V = 2 * W + append
        vq = V >> (F - t)
        zj = jnp.where(vq >= 2, 1, jnp.where(vq >= -2, 0, -1)).astype(jnp.int64)
        is_out = j >= 0
        zj = jnp.where(is_out, zj, 0)
        Zn = jnp.where(is_out, 2 * Z + zj, Z)
        Wn = floor_at(jnp.where(is_out, V - (zj << F), V), T)
        zcol = jnp.clip(j, 0, n - 1)
        zout = jnp.where(
            is_out,
            jax.lax.dynamic_update_index_in_dim(
                zout, zj.astype(jnp.int32), zcol, axis=1),
            zout,
        )
        return Xn, Yn, Wn, Zn, zout

    init = (
        jnp.zeros((B,), jnp.int64),
        jnp.zeros((B,), jnp.int64),
        jnp.zeros((B,), jnp.int64),
        jnp.zeros((B,), jnp.int64),
        jnp.zeros((B, n), jnp.int32),
    )
    X, Y, W, Z, zout = jax.lax.fori_loop(0, n + delta, body, init)
    return zout, Z
