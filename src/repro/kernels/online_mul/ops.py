"""Public jit'd wrappers for the batched online multiplier.

`online_mul` picks the Pallas kernel when the configuration fits the int32
datapath (see kernel.py) and falls back to the int64 jnp reference
otherwise. `online_dot` forwards to the fused inner-product array kernel
(kernels/online_dot), which runs the K multiplier lanes AND the online
adder tree inside one Pallas call — kept here for source compatibility.
"""
from __future__ import annotations

import jax

from repro.core.precision import OnlinePrecision
from repro.kernels.common import (decode_digits, pad_to_multiple,
                                  resolve_use_pallas)
from .kernel import online_mul_pallas
from .ref import online_mul_batch_ref

__all__ = ["online_mul", "online_dot"]


def online_mul(
    x_digits: jax.Array,
    y_digits: jax.Array,
    cfg: OnlinePrecision,
    *,
    use_pallas: bool | None = None,
    block_b: int = 1024,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Batched online multiply.

    Returns (z_digits (B, n) int32 jax array, z_int (B,) host np.int64).
    Dispatches to the Pallas kernel when the int32 datapath suffices (all
    Eq.8-truncated configs up to n=32), else the int64 jnp reference.
    """
    B, n = x_digits.shape
    assert cfg.n == n
    if resolve_use_pallas(cfg, use_pallas):
        xp = pad_to_multiple(x_digits, block_b, 0)
        yp = pad_to_multiple(y_digits, block_b, 0)
        z = online_mul_pallas(
            xp, yp, n=cfg.n, delta=cfg.delta, t=cfg.t,
            truncated=cfg.truncated, tail_gating=cfg.tail_gating,
            tail_guard=cfg.tail_guard, block_b=block_b,
            interpret=interpret)[:B]
    else:
        z, _ = online_mul_batch_ref(
            x_digits, y_digits, n=cfg.n, delta=cfg.delta, t=cfg.t,
            truncated=cfg.truncated, tail_gating=cfg.tail_gating,
            tail_guard=cfg.tail_guard)
    return z, decode_digits(z, n)


def online_dot(
    x_digits: jax.Array,  # (B, K, n) operand digit grids
    y_digits: jax.Array,
    cfg: OnlinePrecision,
    **kw,
) -> jax.Array:
    """Inner products over K pairs per batch row; returns (B,) host float64
    dot values. Forwards to the fused array kernel (kernels/online_dot):
    multiplier lanes + digit-serial online adder tree in one Pallas call,
    digit-exact vs the core/inner_product.py oracle."""
    from repro.kernels.online_dot.ops import online_dot as fused_dot
    _, dot = fused_dot(x_digits, y_digits, cfg, **kw)
    return dot
