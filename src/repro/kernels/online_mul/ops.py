"""Public jit'd wrappers for the batched online multiplier.

`online_mul` picks the Pallas kernel when the configuration fits the int32
datapath (see kernel.py) and falls back to the int64 jnp reference
otherwise. `online_dot_planes` runs the multiplier across a (B, K) operand
grid and accumulates the exact product integers — the PE-array inner
product in one call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.precision import OnlinePrecision
from .kernel import online_mul_pallas
from .ref import online_mul_batch_ref, schedule_arrays

__all__ = ["online_mul", "online_dot"]


def _fits_int32(cfg: OnlinePrecision) -> bool:
    return int(schedule_arrays(cfg).max()) + 3 <= 31


def _decode_digits(z: jax.Array, n: int):
    """Digits -> integer scaled 2^n (host-side int64, exact for n <= 62)."""
    import numpy as np
    w = (np.int64(1) << np.arange(n - 1, -1, -1, dtype=np.int64))
    return np.asarray(z).astype(np.int64) @ w


def online_mul(
    x_digits: jax.Array,
    y_digits: jax.Array,
    cfg: OnlinePrecision,
    *,
    use_pallas: bool | None = None,
    block_b: int = 1024,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Batched online multiply.

    Returns (z_digits (B, n) int32 jax array, z_int (B,) host np.int64).
    Dispatches to the Pallas kernel when the int32 datapath suffices (all
    Eq.8-truncated configs up to n=32), else the int64 jnp reference.
    """
    B, n = x_digits.shape
    assert cfg.n == n
    if use_pallas is None:
        use_pallas = _fits_int32(cfg)
    if use_pallas and _fits_int32(cfg):
        pad = (-B) % block_b
        xp, yp = x_digits, y_digits
        if pad:
            xp = jnp.pad(xp, ((0, pad), (0, 0)))
            yp = jnp.pad(yp, ((0, pad), (0, 0)))
        z = online_mul_pallas(
            xp, yp, n=cfg.n, delta=cfg.delta, t=cfg.t,
            truncated=cfg.truncated, tail_gating=cfg.tail_gating,
            tail_guard=cfg.tail_guard, block_b=block_b,
            interpret=interpret)[:B]
    else:
        z, _ = online_mul_batch_ref(
            x_digits, y_digits, n=cfg.n, delta=cfg.delta, t=cfg.t,
            truncated=cfg.truncated, tail_gating=cfg.tail_gating,
            tail_guard=cfg.tail_guard)
    return z, _decode_digits(z, n)


def online_dot(
    x_digits: jax.Array,  # (B, K, n) operand digit grids
    y_digits: jax.Array,
    cfg: OnlinePrecision,
    **kw,
) -> jax.Array:
    """Inner products over K pairs per batch row via the online multiplier;
    returns (B,) host float64 dot values (products decoded at 2^-n output
    granularity, matching the PE-array + adder-tree semantics up to the
    documented 1-ulp product truncation)."""
    import numpy as np
    B, K, n = x_digits.shape
    _, zint = online_mul(x_digits.reshape(B * K, n),
                         y_digits.reshape(B * K, n), cfg, **kw)
    return (zint.reshape(B, K).astype(np.float64) / (2.0 ** n)).sum(axis=1)
