"""Pallas TPU kernel: batched radix-2 online multiplier digit recurrence.

Hardware adaptation (DESIGN.md §2): the paper's PE array runs one
multiplication per PE with digits streaming through time. On a TPU the
parallel axis is the vector lane: each lane holds one multiplication's
datapath (X, Y, W as int32 fixed point), and the n + delta digit steps run
sequentially inside the kernel. The Fig. 7 truncation schedule is what
makes an int32 datapath possible at n = 32: every architectural quantity
is floored at T(j) <= p = ceil((2n+delta+t)/3) fractional bits (Eq. 8), so
the scale 2^p fits comfortably in 32 bits (p(32) = 23), while the full
design would need n + delta = 35 fractional bits. I.e. the paper's
area-saving truncation *is* the enabler for the narrow TPU datapath —
the same insight, different substrate.

VMEM tiling: the batch is tiled in blocks of `block_b` lanes; digit
matrices (B, n) live in VMEM as int32. All ops are VPU integer ops.

Supported: truncated mode for any n <= 32; full mode for n <= 24
(F = n + delta <= 27 still fits int32 with the +-2 residual range).
Out-of-range configs must use the int64 jnp reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import OnlinePrecision
from repro.kernels.common import checked_schedule

__all__ = ["online_mul_pallas", "mul_digit_loop", "mul_block_shapes"]


def mul_block_shapes(*, n: int, delta: int, block_b: int) -> dict:
    """Per-grid-step VMEM block table: name -> (block shape, dtype).

    The single source for what one grid step of online_mul_pallas keeps
    resident in VMEM — the pallas_call below builds its BlockSpecs from
    it and the olmlint VMEM footprint model (repro.analysis.vmem) sums
    it, so kernel and analyzer cannot disagree about the layout.
    """
    return {
        "sched": ((n + delta,), jnp.int32),
        "x_digits": ((block_b, n), jnp.int32),
        "y_digits": ((block_b, n), jnp.int32),
        "z_digits": ((block_b, n), jnp.int32),
    }


def mul_digit_loop(xd, yd, sched, *, n, delta, t, S):
    """Run the n+delta digit steps of the recurrence for a block of lanes.

    Pure jnp int32 function usable inside any Pallas kernel body: the
    online_mul kernel below calls it directly, and the fused inner-product
    kernel (kernels/online_dot/kernel.py) calls it as the K-lane multiplier
    stage feeding its online adder tree.

    Args:
      xd, yd: (L, n) int32 digits in {-1,0,1}, one multiplication per lane.
      sched:  (n+delta,) int32 T(j) truncation schedule (Fig. 7).
    Returns (L, n) int32 MSDF product digits.
    """
    B = xd.shape[0]

    def floor_at(v, T):
        # two's-complement truncation below 2^-T at scale 2^S
        drop = jnp.maximum(jnp.int32(S) - T, 0).astype(jnp.int32)
        return jax.lax.shift_left(jax.lax.shift_right_arithmetic(v, drop), drop)

    def body(s, carry):
        X, Y, W, zout = carry
        s = s.astype(jnp.int32) if hasattr(s, "astype") else jnp.int32(s)
        j = s - delta
        T = sched[s].astype(jnp.int32)
        q = j + 1 + delta                      # arriving digit position
        in_range = jnp.logical_and(q >= 1, q <= n)
        zero = jnp.int32(0)
        # int32-typed literals throughout: a bare Python int in a where/
        # clip branch traces as a weak int64 aval under x64, breaking the
        # kernel-no-int64 contract even though it folds to the same bits.
        col = jnp.clip(q - 1, zero, jnp.int32(n - 1))
        xn = jnp.where(in_range,
                       jax.lax.dynamic_slice(xd, (zero, col), (B, 1))[:, 0],
                       zero)
        yn = jnp.where(in_range,
                       jax.lax.dynamic_slice(yd, (zero, col), (B, 1))[:, 0],
                       zero)
        # digit weight 2^(S-q); gated to zero once the slice is dead
        wexp = jnp.maximum(jnp.int32(S) - q, 0).astype(jnp.int32)
        wq = jnp.where(q <= jnp.minimum(T, jnp.int32(S)),
                       jax.lax.shift_left(jnp.int32(1), wexp), zero)
        Yf = Y + yn * wq
        term = X * yn + Yf * xn                # SELECTOR mux contributions
        append = floor_at(
            jax.lax.shift_right_arithmetic(term, jnp.int32(delta)), T)
        Xn = floor_at(X + xn * wq, T)
        Yn = floor_at(Yf, T)
        V = 2 * W + append
        vq = jax.lax.shift_right_arithmetic(V, jnp.int32(S - t))  # quarters
        zj = jnp.where(vq >= 2, jnp.int32(1),
                       jnp.where(vq >= -2, zero, jnp.int32(-1)))
        is_out = j >= 0
        zj = jnp.where(is_out, zj, zero)
        Wn = floor_at(jnp.where(is_out, V - jax.lax.shift_left(zj, jnp.int32(S)), V), T)
        zcol = jnp.clip(j, zero, jnp.int32(n - 1))
        upd = jax.lax.dynamic_update_slice(zout, zj[:, None], (zero, zcol))
        zout = jnp.where(is_out, upd, zout)
        return Xn, Yn, Wn, zout

    zeros = jnp.zeros((B,), jnp.int32)
    init = (zeros, zeros, zeros, jnp.zeros((B, n), jnp.int32))
    # The multiplier's architectural output IS the MSDF digit stream; the
    # integer decode (OTFC in hardware) happens outside the kernel.
    # int32 loop bounds: Python-int bounds would canonicalize the loop
    # index to int64 under x64, breaking the kernel-no-int64 contract.
    _, _, _, zout = jax.lax.fori_loop(jnp.int32(0), jnp.int32(n + delta),
                                      body, init)
    return zout


def _kernel(sched_ref, x_ref, y_ref, z_ref, *, n, delta, t, S):
    """One batch block: run the n+delta digit steps for block_b lanes."""
    z_ref[...] = mul_digit_loop(x_ref[...], y_ref[...], sched_ref[...],
                                n=n, delta=delta, t=t, S=S)


@functools.partial(
    jax.jit,
    static_argnames=("n", "delta", "t", "truncated", "tail_gating",
                     "tail_guard", "block_b", "interpret"),
)
def online_mul_pallas(
    x_digits: jax.Array,   # (B, n) int32 digits in {-1,0,1}
    y_digits: jax.Array,
    *,
    n: int,
    delta: int = 3,
    t: int = 2,
    truncated: bool = True,
    tail_gating: bool = True,
    tail_guard: int = 2,
    block_b: int = 1024,
    interpret: bool = True,  # CPU container: interpret; False on real TPU
) -> tuple[jax.Array, jax.Array]:
    """Pallas-tiled batched online multiplication.

    Returns z_digits (B, n) int32 — the MSDF digit stream (exact for all
    supported n). Integer/float decoding is done by the ops.py wrapper.
    """
    cfg = OnlinePrecision(n=n, delta=delta, t=t, truncated=truncated,
                          tail_gating=tail_gating, tail_guard=tail_guard)
    # datapath scale 2^S; S == p (truncated) or n+delta (full)
    sched_np, S = checked_schedule(cfg)
    B = x_digits.shape[0]
    if B % block_b:
        raise ValueError(f"batch {B} must be divisible by block_b {block_b}")
    sched = jnp.asarray(sched_np)
    grid = (B // block_b,)
    kern = functools.partial(_kernel, n=n, delta=delta, t=t, S=S)
    blocks = mul_block_shapes(n=n, delta=delta, block_b=block_b)
    z = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(blocks["sched"][0], lambda i: (0,)),  # sched (bcast)
            pl.BlockSpec(blocks["x_digits"][0], lambda i: (i, 0)),
            pl.BlockSpec(blocks["y_digits"][0], lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec(blocks["z_digits"][0], lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n), jnp.int32),
        interpret=interpret,
    )(sched, x_digits.astype(jnp.int32), y_digits.astype(jnp.int32))
    return z
