"""Public jit'd wrapper for the fused online inner-product array.

`online_dot` mirrors the online_mul dispatch: the fused Pallas kernel when
the configuration fits the int32 datapath (every Eq. 8-truncated config up
to n = 32), else the int64 jnp reference. Dispatch/decoding plumbing is
shared with the other kernel families via kernels/common.py.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.precision import OnlinePrecision
from repro.kernels.common import (decode_stream, pad_to_multiple,
                                  resolve_use_pallas)
from .kernel import online_dot_pallas
from .ref import online_dot_batch_ref, tree_levels

__all__ = ["online_dot", "dot_scale_log2", "dot_stream_length"]


def dot_scale_log2(k: int) -> int:
    """L: the emitted stream encodes sum x_i y_i / 2^L."""
    return tree_levels(k)


def dot_stream_length(n: int, k: int) -> int:
    """Digits in the emitted stream: n + 2 per adder-tree level."""
    return n + 2 * tree_levels(k)


def online_dot(
    x_digits: jax.Array,  # (B, K, n) operand digit grids in {-1,0,1}
    y_digits: jax.Array,
    cfg: OnlinePrecision,
    *,
    use_pallas: bool | None = None,
    block_b: int = 8,
    interpret: bool = True,
) -> tuple[jax.Array, np.ndarray]:
    """Batched fused online inner product over K pairs per batch row.

    Returns (z_digits (B, n + 2L) int32 jax array, dot (B,) host float64
    inner-product values with the 2^-L tree scale already removed). The
    digit stream is bit-exact vs the core/inner_product.online_dot oracle;
    the value inherits the multiplier's <= 1.1 ulp/product truncation.
    """
    B, K, n = x_digits.shape
    assert cfg.n == n
    kw = dict(n=cfg.n, delta=cfg.delta, t=cfg.t, truncated=cfg.truncated,
              tail_gating=cfg.tail_gating, tail_guard=cfg.tail_guard)
    if resolve_use_pallas(cfg, use_pallas):
        xp = pad_to_multiple(x_digits, block_b, 0)
        yp = pad_to_multiple(y_digits, block_b, 0)
        z = online_dot_pallas(xp, yp, block_b=block_b,
                              interpret=interpret, **kw)[:B]
    else:
        z = online_dot_batch_ref(x_digits, y_digits, **kw)
    L = tree_levels(K)
    return z, decode_stream(z) * float(1 << L)
