"""Mesh-sharded front-end for the olm matmul: shard_map over the array.

The digit-serial inner-product array is embarrassingly parallel along the
output dimensions — partitioning a GEMM into independent lanes is the same
move ChipFlow's partitioned multiplier makes in hardware. This module
wraps the single-device `olm_matmul` (grid/fused Pallas kernel or the
broadcast oracle) in `shard_map` so every shard runs the unchanged array
kernel on its local tile:

``partition="m"`` / ``"n"``
    Tensor-parallel output sharding: each device owns M/d rows (or N/d
    columns) of the output and the FULL contraction. No collective runs
    and every per-shard K-tile accumulation is the same sequential order
    as single-device, so the sharded output is **bit-identical** to the
    single-device kernel — block shapes are bit-invariant and k_tile is
    whatever the caller (or the autotuner's pinned default) says.

``partition="k"``
    Contraction sharding: each device computes a full (M, N) partial sum
    over its K/d slice, then the f32 partial accumulators are combined
    with `jax.lax.psum`. The total number of additions per output element
    is unchanged, but the **reduction order differs** from the
    single-device kernel's sequential K-tile walk (the collective adds
    d per-shard subtotals instead). The result is therefore NOT
    bit-identical; it stays within `olm_error_bound` (each shard's
    contribution is bounded by its own tiles' ledger and f32 addition is
    order-sensitive only below the bound's ulp resolution — the wide
    (T + 1) * 2^-26 term already covers one rounding per tile plus the
    accumulator roundings, which is exactly what the psum re-spends).
    This is the one documented numerics caveat of the distributed path.

tiling="auto" resolves the grid knobs against the per-shard LOCAL shapes,
so a sharded GEMM lands in the same autotuner bucket as an equivalent
single-device GEMM of the shard size (a decode GEMV sharded 8-way over N
tunes like an N/8 GEMV, not like the global shape). Explicit knob pins
win, and auto never changes k_tile (tuning.pinned_k_tile), so auto vs
static cannot change bits on the m/n paths.

The n = 32 broadcast-oracle path needs real int64: `shard_map` bodies are
always traced, so the `enable_x64` scope is hoisted OUT of the body and
wrapped around the eager shard_map call here (mirroring olm_matmul's own
host-wrapper rule that the scope is only safe around an eager entry).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import enable_x64, shard_map
from repro.kernels.common import int64_enabled, resolve_use_pallas

from .matmul import (DEFAULT_BLOCK_M, DEFAULT_BLOCK_N, DEFAULT_K_TILE,
                     DEFAULT_QUANTIZE, _olm_cfg, digit_traffic, olm_matmul)
from .ref import oracle_needs_x64

__all__ = ["olm_matmul_sharded", "gemm_partition_specs", "local_shapes",
           "sharded_traffic"]

_PARTITIONS = ("m", "n", "k")


def gemm_partition_specs(partition: str, axis: str = "model"):
    """((x_spec, w_spec), out_spec) for a GEMM sharded on `partition`.

    m: x rows sharded, w replicated, output rows sharded.
    n: x replicated, w columns sharded, output columns sharded.
    k: x columns + w rows co-sharded, output replicated (post-psum).
    """
    if partition == "m":
        return (P(axis, None), P(None, None)), P(axis, None)
    if partition == "n":
        return (P(None, None), P(None, axis)), P(None, axis)
    if partition == "k":
        return (P(None, axis), P(axis, None)), P(None, None)
    raise ValueError(
        f"unknown GEMM partition {partition!r}; expected one of "
        f"{_PARTITIONS}")


def local_shapes(M: int, N: int, K: int, partition: str,
                 devices: int) -> tuple:
    """Per-shard (M, N, K) under `partition` over `devices` shards.
    Raises when the partitioned dimension does not divide evenly —
    shard_map gives no padding, and silent padding would change the
    digit-tile plan (and with it the error ledger) per shard."""
    if partition not in _PARTITIONS:
        raise ValueError(
            f"unknown GEMM partition {partition!r}; expected one of "
            f"{_PARTITIONS}")
    dim = {"m": M, "n": N, "k": K}[partition]
    if dim % devices:
        raise ValueError(
            f"partition={partition!r} needs {partition.upper()} divisible "
            f"by the mesh axis size; got {dim} over {devices} devices")
    return {"m": (M // devices, N, K),
            "n": (M, N // devices, K),
            "k": (M, N, K // devices)}[partition]


def olm_matmul_sharded(
    x: jax.Array,  # (M, K) float
    w: jax.Array,  # (K, N) float
    *,
    mesh: jax.sharding.Mesh,
    partition: str = "m",
    axis: str = "model",
    n_bits: int = 16,
    k_tile: Optional[int] = None,
    trunc: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    quantize: str = DEFAULT_QUANTIZE,
    interpret: bool = True,
    tiling: Optional[str] = None,
) -> jax.Array:
    """`olm_matmul` sharded over `mesh`'s `axis`; (M, N) float32.

    partition="m"/"n" shard the output rows/columns (bit-identical to
    single-device); partition="k" shards the contraction and psums the
    f32 partials (within olm_error_bound; reduction order differs — see
    the module docstring). Unlike `olm_matmul`, the grid knobs default
    to None = "kernel default, or the autotuner's pick when
    tiling='auto'" so pinned knobs stay distinguishable from defaults.
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: x (M,{K}) @ w ({K2},N)")
    if tiling not in (None, "auto"):
        raise ValueError(f"tiling must be 'auto' or None, got {tiling!r}")
    if axis not in mesh.shape:
        raise ValueError(
            f"mesh has no axis {axis!r}; axes: {tuple(mesh.axis_names)}")
    d = int(mesh.shape[axis])
    Ml, Nl, Kl = local_shapes(M, N, K, partition, d)

    knobs = {k: v for k, v in (("k_tile", k_tile), ("block_m", block_m),
                               ("block_n", block_n)) if v is not None}
    if tiling == "auto" and use_pallas is not False:
        # Same bucket as a single-device GEMM of the LOCAL shard shape.
        from .tuning import get_tiling
        auto = get_tiling(Ml, Nl, Kl, n_bits, trunc=trunc)
        knobs = {**auto, **knobs}
    kt = knobs.get("k_tile", DEFAULT_K_TILE)
    bm = knobs.get("block_m", DEFAULT_BLOCK_M)
    bn = knobs.get("block_n", DEFAULT_BLOCK_N)

    in_specs, out_spec = gemm_partition_specs(partition, axis)

    def body(xs, ws):
        out = olm_matmul(xs, ws, n_bits=n_bits, k_tile=kt, trunc=trunc,
                         use_pallas=use_pallas, block_m=bm, block_n=bn,
                         quantize=quantize, interpret=interpret)
        if partition == "k":
            out = jax.lax.psum(out, axis)
        return out

    fn = shard_map(body, mesh, in_specs=in_specs, out_specs=out_spec)

    # The shard_map body is always traced, so olm_matmul's own eager
    # enable_x64 wrap can never fire inside it — hoist the scope around
    # the shard_map call when the resolved path is the n = 32 oracle.
    work = trunc if trunc is not None else n_bits
    cfg = _olm_cfg(work)
    use = resolve_use_pallas(cfg, use_pallas)
    if not use and oracle_needs_x64(cfg.n, cfg.delta) and not int64_enabled():
        if isinstance(x, jax.core.Tracer) or isinstance(w, jax.core.Tracer):
            raise ValueError(
                f"the n_bits={work} broadcast-oracle path needs int64 "
                "but olm_matmul_sharded was called inside an already-"
                "traced computation: wrap the outer jit call in "
                "repro.compat.enable_x64(), or use the Pallas path "
                "(use_pallas=None/True), whose Eq.8-truncated datapath "
                "fits int32")
        with enable_x64():
            return fn(x, w)
    return fn(x, w)


def sharded_traffic(M: int, N: int, K: int, *, partition: str,
                    devices: int, n_bits: int = 16,
                    k_tile: int = DEFAULT_K_TILE,
                    trunc: Optional[int] = None,
                    block_m: int = DEFAULT_BLOCK_M,
                    block_n: int = DEFAULT_BLOCK_N) -> dict:
    """Movement ledger for one sharded GEMM: the per-device LOCAL digit
    traffic (matmul.digit_traffic on the shard shapes) plus the total
    collective bytes on the wire. m/n move nothing between devices; k
    all-reduces an (M, N) f32 buffer — modeled as ring reduce-scatter +
    all-gather, 2 * 4 * M * N * (devices - 1) bytes total."""
    Ml, Nl, Kl = local_shapes(M, N, K, partition, devices)
    local = digit_traffic(Ml, Nl, Kl, n_bits=n_bits, k_tile=k_tile,
                          trunc=trunc, block_m=block_m, block_n=block_n)
    collective = 0 if partition in ("m", "n") else 8 * M * N * (devices - 1)
    return {"partition": partition, "devices": devices,
            "local": local, "collective_bytes": collective}
