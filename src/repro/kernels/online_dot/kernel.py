"""Pallas TPU kernel: fused online inner-product array.

One kernel runs the paper's whole array-level datapath (the inner-product
target workload of §IV and of the follow-up array paper): for each batch
row, K vector lanes execute the radix-2 online-multiplier digit recurrence
(the Fig. 7 truncation schedule from kernels/online_mul, int32 datapath),
and their MSDF product digit streams are reduced by a balanced online-adder
tree (delta_add = 2 per level, the core/online_add.py recurrence vectorized
position-parallel over lanes). The kernel emits the dot-product digit
stream sum_i x_i y_i / 2^L directly — no full-precision product integer is
ever materialized, exactly like the hardware array.

Layout: operands are (block_b, K, n) int32 digit blocks in VMEM; the
multiplier stage flattens the (block_b * K) lanes onto the vector axis and
runs the n + delta digit steps sequentially (VPU integer ops); the tree
stage is ceil(log2 K) statically-unrolled vectorized levels. Datapath
bounds are the multiplier's (max T(j) + 3 <= 31); tree digits stay in
{-2..2} and never stress int32.

interpret=True on the CPU container; flip to False on a real TPU (ROADMAP
open item: validate the Mosaic lowering of the 3-D block reshape there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import OnlinePrecision
from repro.kernels.common import checked_schedule
from repro.kernels.online_mul.kernel import mul_digit_loop
from .ref import adder_tree, tree_levels

__all__ = ["online_dot_pallas", "lane_tree", "dot_block_shapes"]


def dot_block_shapes(*, n: int, delta: int, K: int, block_b: int) -> dict:
    """Per-grid-step VMEM block table: name -> (block shape, dtype).

    Single source for the online_dot_pallas layout — the pallas_call
    below builds its BlockSpecs from it and the olmlint VMEM footprint
    model (repro.analysis.vmem) sums it.
    """
    m_out = n + 2 * tree_levels(K)
    return {
        "sched": ((n + delta,), jnp.int32),
        "x_digits": ((block_b, K, n), jnp.int32),
        "y_digits": ((block_b, K, n), jnp.int32),
        "z_stream": ((block_b, m_out), jnp.int32),
    }


def lane_tree(xd, yd, sched, *, n, delta, t, S):
    """The fused array datapath for one digit block: K-lane multiplier
    recurrence + position-parallel online adder tree.

    Pure jnp int32 function usable inside any Pallas kernel body — the
    batched dot kernel below and the grid-tiled matmul kernel
    (matmul_kernel.py) both call it, so the two kernels share the exact
    digit arithmetic by construction.

    Args:
      xd, yd: (B, K, n) int32 digits in {-1,0,1}.
      sched:  (n+delta,) int32 T(j) truncation schedule (Fig. 7).
    Returns (B, n + 2*ceil(log2 K)) int32 dot-stream digits.
    """
    B, K, _ = xd.shape
    prod = mul_digit_loop(xd.reshape(B * K, n), yd.reshape(B * K, n),
                          sched, n=n, delta=delta, t=t, S=S)
    out, _ = adder_tree(prod.reshape(B, K, n))
    return out


def _kernel(sched_ref, x_ref, y_ref, z_ref, *, n, delta, t, S):
    """One batch block: K-lane multiplier recurrence + online adder tree."""
    z_ref[...] = lane_tree(x_ref[...], y_ref[...], sched_ref[...],
                           n=n, delta=delta, t=t, S=S)


@functools.partial(
    jax.jit,
    static_argnames=("n", "delta", "t", "truncated", "tail_gating",
                     "tail_guard", "block_b", "interpret"),
)
def online_dot_pallas(
    x_digits: jax.Array,   # (B, K, n) int32 digits in {-1,0,1}
    y_digits: jax.Array,
    *,
    n: int,
    delta: int = 3,
    t: int = 2,
    truncated: bool = True,
    tail_gating: bool = True,
    tail_guard: int = 2,
    block_b: int = 8,
    interpret: bool = True,  # CPU container: interpret; False on real TPU
) -> jax.Array:
    """Fused batched online inner product.

    Returns (B, n + 2*ceil(log2 K)) int32 — the MSDF digit stream of
    sum_i x_i y_i / 2^L, bit-exact vs core/inner_product.online_dot.
    Decoding is done by the ops.py wrapper.
    """
    cfg = OnlinePrecision(n=n, delta=delta, t=t, truncated=truncated,
                          tail_gating=tail_gating, tail_guard=tail_guard)
    sched_np, S = checked_schedule(cfg)
    B, K, n_ = x_digits.shape
    if n_ != n:
        raise ValueError(f"operand digit count {n_} != cfg n {n}")
    if B % block_b:
        raise ValueError(f"batch {B} must be divisible by block_b {block_b}")
    m_out = n + 2 * tree_levels(K)
    sched = jnp.asarray(sched_np)
    grid = (B // block_b,)
    kern = functools.partial(_kernel, n=n, delta=delta, t=t, S=S)
    blocks = dot_block_shapes(n=n, delta=delta, K=K, block_b=block_b)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(blocks["sched"][0], lambda i: (0,)),    # schedule
            pl.BlockSpec(blocks["x_digits"][0], lambda i: (i, 0, 0)),
            pl.BlockSpec(blocks["y_digits"][0], lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec(blocks["z_stream"][0], lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m_out), jnp.int32),
        interpret=interpret,
    )(sched, x_digits.astype(jnp.int32), y_digits.astype(jnp.int32))
