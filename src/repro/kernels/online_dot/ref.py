"""jnp reference for the fused online inner-product array.

Two pieces:

* ``adder_tree`` — the balanced online-adder tree of core/online_add.py
  vectorized over (batch, node, digit) axes. The streaming OnlineAdder
  recurrence closes over a 2-digit window, so the whole stream can be
  computed position-parallel: with e_k the padded digit sums and the flush
  zeros appended,

      t_k = +1 if e_k >= 2 or (e_k == +1 and e_{k+1} >= 0)
      t_k = -1 if e_k <= -2 or (e_k == -1 and e_{k+1} <  0)
      w_k = e_k - 2 t_k,     out_k = w_k + t_{k+1}

  which is a pure elementwise map over shifted views — no serial loop.
  Each level halves the node count (odd levels zero-padded, exactly like
  core/inner_product._tree_reduce) and grows the stream by 2 digits (the
  /2 pre-scale plus the adder delay drain).

* ``online_dot_batch_ref`` — K-lane multiplier (the int64 jnp reference
  recurrence from kernels/online_mul/ref.py) feeding ``adder_tree``.
  Property-tested bit-identical to the core/inner_product.online_dot
  oracle; this is what the Pallas kernel is asserted against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.online_mul.ref import online_mul_batch_ref

__all__ = ["adder_tree", "tree_levels", "oracle_needs_x64",
           "online_dot_batch_ref"]


def oracle_needs_x64(n: int, delta: int = 3) -> bool:
    """True when the full-width reference recurrence (this module's int64
    oracle, via online_mul_batch_ref) overflows a canonicalized-to-int32
    datapath: its registers span F = n + delta bits plus 3 bits of
    residual/selection headroom. The Eq.8-*truncated* Pallas datapath
    fits int32 at every ARRAY_PRECISIONS width (max T(j) + 3 <= 31 even
    at n = 32 — the paper's reduced-working-precision point), but the
    untruncated-width oracle needs real int64 above n = 25, so the
    matmul front-end scopes its n = 32 oracle path under
    repro.compat.enable_x64 when x64 is not already on."""
    return n + delta + 3 > 31


def tree_levels(k: int) -> int:
    """Number of reduction levels L for k lanes (== ceil(log2 k), 0 for 1)."""
    if k < 1:
        raise ValueError(f"need k >= 1 lanes, got {k}")
    levels, width = 0, k
    while width > 1:
        width = (width + 1) // 2
        levels += 1
    return levels


def adder_tree(streams: jax.Array) -> tuple[jax.Array, int]:
    """Reduce (B, K, m) SD digit streams through the online adder tree.

    Returns ((B, m + 2L) digit stream of sum/2^L, L). Digit arithmetic
    stays in the input integer dtype (values never leave {-2..2} before
    the final {-1,0,1} output), so int32 suffices on any datapath.
    """
    B = streams.shape[0]
    dt = streams.dtype
    levels = 0
    while streams.shape[1] > 1:
        K, m = streams.shape[1], streams.shape[2]
        if K % 2:
            streams = jnp.concatenate(
                [streams, jnp.zeros((B, 1, m), dt)], axis=1)
            K += 1
        pairs = streams.reshape(B, K // 2, 2, m)
        # e_0 = 0 (the /2 pre-scale shift), e_1..e_m the digit sums, then
        # two flush zeros draining the delay line.
        e = jnp.concatenate(
            [jnp.zeros((B, K // 2, 1), dt),
             pairs[:, :, 0, :] + pairs[:, :, 1, :],
             jnp.zeros((B, K // 2, 2), dt)], axis=-1)
        ek, en = e[..., :-1], e[..., 1:]
        # dt-typed literals: bare Python ints in where branches trace as
        # weak int64 under x64 (kernel-no-int64 — lane_tree runs this
        # loop inside the Pallas dot kernel body).
        one, zero = jnp.asarray(1, dt), jnp.asarray(0, dt)
        t = jnp.where(
            (ek >= 2) | ((ek == 1) & (en >= 0)), one,
            jnp.where((ek <= -2) | ((ek == -1) & (en < 0)), -one, zero),
        )
        w = ek - 2 * t
        out = w[..., :-1] + t[..., 1:]
        streams = jnp.concatenate(
            [out, jnp.zeros((B, K // 2, 1), dt)], axis=-1)
        levels += 1
    return streams[:, 0, :], levels


@functools.partial(jax.jit, static_argnames=("n", "delta", "t", "truncated",
                                             "tail_gating", "tail_guard"))
def online_dot_batch_ref(
    x_digits: jax.Array,  # (B, K, n) int32 digits in {-1,0,1}
    y_digits: jax.Array,  # (B, K, n)
    *,
    n: int,
    delta: int = 3,
    t: int = 2,
    truncated: bool = True,
    tail_gating: bool = True,
    tail_guard: int = 2,
) -> jax.Array:
    """Batched online inner product, reference path.

    Returns (B, n + 2*ceil(log2 K)) int32 SD digits of
    sum_i x_i y_i / 2^L. Needs x64 enabled (repro.compat.enable_x64) when
    the multiplier's full-width recurrence exceeds int32, same as
    online_mul_batch_ref.
    """
    B, K, n_ = x_digits.shape
    z, _ = online_mul_batch_ref(
        x_digits.reshape(B * K, n), y_digits.reshape(B * K, n),
        n=n, delta=delta, t=t, truncated=truncated,
        tail_gating=tail_gating, tail_guard=tail_guard)
    out, _ = adder_tree(z.reshape(B, K, n))
    return out
