"""Shape-aware tiling autotuner for the olm grid matmul.

The grid kernel's three knobs — (k_tile, block_m, block_n) — were a
single static default (`configs/olm_array.MATMUL_TILING`, 16/8/8)
regardless of GEMM shape, which is wrong at both extremes: a decode
GEMV (M=1) wastes its whole block_m dimension, and a fat training GEMM
leaves reuse on the table with an 8x8 tile. This module replaces the
static default with a measured-or-heuristic lookup keyed on
power-of-two buckets of (M, N, K, n_bits):

  * `get_tiling(M, N, K, n_bits)` — the lookup the DotEngine
    `tiling="auto"` path calls per GEMM shape at trace time. Cache hit
    returns the stored entry (measured if `tune` ran, else the
    memoized heuristic); miss computes `heuristic_tiling` and memoizes
    it, so repeated traces of the same bucket are hits.
  * `tune(M, N, K, n_bits)` — measures a small candidate grid around
    the heuristic with `olm_matmul` on random data (shapes capped so
    tuning stays CPU-friendly; the bucket key still records the real
    shape class) and persists the winner.
  * `TuningCache` — the persistent JSON store, default
    `results/tuning.json` (`REPRO_TUNING_CACHE` overrides; `make tune`
    populates it for the launch/shapes.py shape set via the CLI below).

`tiling="auto"` is a pure performance choice that cannot change
numerics, and the knob split is what guarantees that: block_m/block_n
only re-tile the *output* (the quantizer, digit arithmetic, decode,
and K-tile accumulation order are all block-invariant — bit-identity
is property-tested), so the tuner explores them freely; k_tile, by
contrast, is a numerics parameter — it sets the quantization slice
width, adder-tree depth, and the per-K-tile term of olm_error_bound —
so the auto path pins it to the kernel default (DEFAULT_K_TILE,
clamped to K exactly as the kernel itself does) and a different
k_tile must be an explicit caller choice (`DotEngine(k_tile=...)`,
which wins over the tuner). Every candidate also respects the
per-dtype exact decode window (`decode_window`: 24 digits plain-f32
for n <= 16, 48 digits wide decode for n = 24/32) and the VMEM lane
budget, so autotuning can never select a configuration the kernel
would refuse.

CLI (what `make tune` runs):

  PYTHONPATH=src python -m repro.kernels.online_dot.tuning \
      [--cache results/tuning.json] [--heuristic-only] [--cap 48]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, Optional

from repro.kernels.common import DECODE_WINDOW_F32, DECODE_WINDOW_WIDE

from .ref import tree_levels

__all__ = ["Tiling", "TuningCache", "bucket", "bucket_key",
           "decode_window", "lane_budget", "max_k_tile", "pinned_k_tile",
           "heuristic_tiling", "get_tiling", "tune", "default_cache"]

# In-kernel lane batch budget (block_m * block_n * k_tile): the fused
# kernel materializes this many multiplier lanes in VMEM per grid step.
# 2048 keeps the digit matrices ((lanes, kt, n) int32) comfortably
# inside a ~16 MB VMEM at the reference width n = 16 while leaving room
# to grow blocks. Width-aware consumers use `lane_budget(n_bits)`.
LANE_BUDGET = 2048
LANE_BUDGET_REF_BITS = 16


def lane_budget(n_bits: int) -> int:
    """Width-aware VMEM lane batch budget: the per-lane digit matrices
    are (kt, n) int32, so VMEM cost per lane is linear in n_bits and the
    lane count the same VMEM affords shrinks as 1/n_bits. Scaled off the
    n = 16 reference (lane_budget(16) == LANE_BUDGET, the historical
    width-blind constant) and floored to a power of two so the
    heuristic's block splits stay power-of-two shaped.

    This is the ONE budget function: `heuristic_tiling`/`_candidates`
    spend it and the olmlint static analyzer's VMEM footprint check
    (repro.analysis.vmem) enforces it, so tuner and lint can't disagree
    about what fits."""
    return _pow2_floor(max(1, (LANE_BUDGET * LANE_BUDGET_REF_BITS) // n_bits))


def decode_window(n_bits: int) -> int:
    """Per-dtype exact decode window the tuner must keep streams inside:
    n <= 16 stays on the plain-f32 path (24 digits — by policy, not
    necessity: a 25..48-digit n = 16 stream *would* decode exactly on
    the wide path, but auto tilings must stay bit-identical to the
    static default, whose streams are f32-narrow); n = 24/32 have no
    f32-narrow tiling at all, so they get the 48-digit wide window
    (kernels/common.DECODE_WINDOW_WIDE)."""
    return DECODE_WINDOW_F32 if n_bits <= 16 else DECODE_WINDOW_WIDE

# Anchored to the repo root (four levels above this file's package
# directory), not the CWD: `make tune` from the repo root and a serving
# process launched from anywhere must agree on where the cache lives.
# REPRO_TUNING_CACHE overrides for deployments with their own layout.
_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "..", ".."))
DEFAULT_CACHE_PATH = os.path.join(_REPO_ROOT, "results", "tuning.json")


@dataclasses.dataclass(frozen=True)
class Tiling:
    """One grid-kernel configuration, the value the autotuner trades in."""
    k_tile: int
    block_m: int
    block_n: int

    def as_dict(self) -> Dict[str, int]:
        return {"k_tile": self.k_tile, "block_m": self.block_m,
                "block_n": self.block_n}


def _pow2_ceil(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


def _pow2_floor(v: int) -> int:
    return 1 << max(0, int(v).bit_length() - 1)


def bucket(v: int) -> int:
    """Shape bucket: the next power of two (>= 1). GEMM dims within one
    bucket share a tiling entry, so the cache stays O(log shapes)."""
    return _pow2_ceil(max(1, v))


def bucket_key(M: int, N: int, K: int, n_bits: int,
               trunc: Optional[int] = None) -> str:
    """Cache key for one (shape bucket, numerics) pair. The truncated
    `olm{n}t{p}` modes carry a `t{p}` suffix: they run the kernel at p
    working digits, so their VMEM budget, decode window and measured
    timings all differ from the full-precision mode of the same n — a
    shared entry would let one mode's tuning silently steer the other's
    (and a p-digit k_tile could exceed the n-digit decode window)."""
    suffix = "" if trunc is None else f"t{trunc}"
    return f"m{bucket(M)}n{bucket(N)}k{bucket(K)}b{n_bits}{suffix}"


def max_k_tile(n_bits: int) -> int:
    """Largest power-of-two k_tile whose dot stream still decodes
    exactly on this width's decode path:
    n_bits + 2*ceil(log2 kt) <= decode_window(n_bits)."""
    window = decode_window(n_bits)
    kt = 1
    while n_bits + 2 * tree_levels(kt * 2) <= window:
        kt *= 2
    return kt


def pinned_k_tile(K: int, n_bits: int) -> int:
    """The k_tile `tiling="auto"` always serves: the kernel numerics
    default clamped to the K bucket and the per-dtype decode window —
    the ONE formula behind the never-changes-numerics guarantee. The
    auto path, the heuristic, and tools/check_bench.py's tuning-cache
    guard all call this, so the invariant can't drift between them."""
    from .matmul import DEFAULT_K_TILE
    return min(DEFAULT_K_TILE, _pow2_ceil(K), max_k_tile(n_bits))


def heuristic_tiling(M: int, N: int, K: int, n_bits: int,
                     trunc: Optional[int] = None) -> Tiling:
    """Shape-aware default when nothing has been measured for a bucket.

    k_tile is pinned to the kernel's numerics default (DEFAULT_K_TILE,
    clamped to K exactly like the kernel's own kt = min(k_tile, K)) —
    it sets the quantization slice width and adder-tree depth, so
    letting the tuner move it would change results; see the module
    docstring. The width-aware `lane_budget(n_bits)` residual is then
    split between block_m and block_n near-square, each capped at its
    output dim — so a GEMV (M=1) spends the whole budget on block_n
    instead of wasting 7/8 of an 8x8 tile on nonexistent rows, and the
    wide modes (n = 24/32, whose digit grids cost 1.5-2x the VMEM per
    lane) get proportionally smaller blocks.

    Truncated modes (trunc=p) spend the budget at their *working*
    digits: the kernel they run is the p-digit array, so VMEM cost and
    decode window are p's — a p/n-cheaper lane lets the truncated mode
    afford proportionally larger blocks than its full-width parent.
    """
    work = n_bits if trunc is None else trunc
    # pinned_k_tile keeps the decode-window guarantee structural even if
    # DEFAULT_K_TILE is ever raised past what a given n_bits allows
    kt = pinned_k_tile(K, work)
    per_out = max(1, lane_budget(work) // kt)  # block_m * block_n budget
    bm = min(_pow2_ceil(M), _pow2_floor(max(1, int(per_out ** 0.5))))
    bn = min(_pow2_ceil(N), max(1, per_out // bm))
    bm = min(_pow2_ceil(M), max(1, per_out // bn))   # regrow if N was small
    return Tiling(k_tile=kt, block_m=bm, block_n=bn)


class TuningCache:
    """Persistent (bucket key -> tiling entry) store with hit/miss
    accounting. Entries are plain JSON dicts:

      {"k_tile": .., "block_m": .., "block_n": ..,
       "source": "measured" | "heuristic",
       "shape": [M, N, K], "n_bits": ..,
       "trunc": .. (truncated olm{n}t{p} entries only),
       "us": .. (measured only)}

    Disk writes only happen via `save()` (the `tune` path); heuristic
    memoization stays in memory so tracing a model never writes files.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path if path is not None else os.environ.get(
            "REPRO_TUNING_CACHE", DEFAULT_CACHE_PATH)
        self.hits = 0
        self.misses = 0
        self._entries: Optional[Dict[str, dict]] = None

    # -- storage --
    def _load(self) -> Dict[str, dict]:
        if self._entries is None:
            self._entries = {}
            if self.path and os.path.exists(self.path):
                with open(self.path) as f:
                    data = json.load(f)
                self._entries = dict(data.get("entries", {}))
        return self._entries

    def save(self) -> None:
        entries = self._load()
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"entries": entries}, f, indent=1, sort_keys=True)

    # -- lookup API --
    def lookup(self, M: int, N: int, K: int, n_bits: int,
               trunc: Optional[int] = None) -> Optional[Tiling]:
        e = self._load().get(bucket_key(M, N, K, n_bits, trunc))
        if e is None:
            self.misses += 1
            return None
        self.hits += 1
        return Tiling(e["k_tile"], e["block_m"], e["block_n"])

    def store(self, M: int, N: int, K: int, n_bits: int, tiling: Tiling,
              *, source: str, trunc: Optional[int] = None,
              us: Optional[float] = None) -> None:
        entry = {**tiling.as_dict(), "source": source,
                 "shape": [M, N, K], "n_bits": n_bits}
        if trunc is not None:
            entry["trunc"] = trunc
        if us is not None:
            entry["us"] = round(us, 2)
        self._load()[bucket_key(M, N, K, n_bits, trunc)] = entry


_DEFAULT_CACHE: Optional[TuningCache] = None


def default_cache() -> TuningCache:
    """The process-wide cache `tiling="auto"` reads (lazy singleton, so
    REPRO_TUNING_CACHE set before first use is honored)."""
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None:
        _DEFAULT_CACHE = TuningCache()
    return _DEFAULT_CACHE


def get_tiling(M: int, N: int, K: int, n_bits: int,
               cache: Optional[TuningCache] = None,
               trunc: Optional[int] = None) -> Dict[str, int]:
    """Measured-or-heuristic tiling for one GEMM shape (the
    `tiling="auto"` entry point; shapes are static at trace time so
    this runs on the host during tracing). Cache miss falls back to
    `heuristic_tiling` and memoizes it in-memory, so the next trace of
    the same bucket is a hit.

    k_tile is re-pinned to the numerics default on every read — not
    just at write time — so the never-changes-numerics guarantee is
    structural: a cache file written by an older version, a different
    DEFAULT_K_TILE, or a hand edit can adjust blocks (pure perf) but
    can never alter what `tiling="auto"` computes.

    Truncated modes pass trunc=p: the bucket key grows a `t{p}` suffix
    (no sharing with the same-n full mode) and k_tile re-pins against
    the p-digit decode window — the width the kernel actually runs."""
    cache = cache or default_cache()
    pinned = pinned_k_tile(K, n_bits if trunc is None else trunc)
    hit = cache.lookup(M, N, K, n_bits, trunc)
    if hit is not None:
        return {**hit.as_dict(), "k_tile": pinned}
    t = heuristic_tiling(M, N, K, n_bits, trunc)
    cache.store(M, N, K, n_bits, t, source="heuristic", trunc=trunc)
    return {**t.as_dict(), "k_tile": pinned}


def _candidates(M: int, N: int, K: int, n_bits: int,
                trunc: Optional[int] = None) -> list[Tiling]:
    """Small candidate grid around the heuristic: the heuristic itself,
    the static legacy block shape, and block halvings/doublings that
    stay inside the lane budget and output dims. k_tile is pinned to
    the heuristic's numerics-default value for every candidate (see
    module docstring) — the tuner only races bit-identical tilings."""
    work = n_bits if trunc is None else trunc
    base = heuristic_tiling(M, N, K, n_bits, trunc)
    kt = base.k_tile
    cands = {base,
             Tiling(kt, min(8, _pow2_ceil(M)), min(8, _pow2_ceil(N)))}
    for bm in {base.block_m, max(1, base.block_m // 2),
               min(_pow2_ceil(M), base.block_m * 2)}:
        for bn in {base.block_n, max(1, base.block_n // 2),
                   min(_pow2_ceil(N), base.block_n * 2)}:
            if bm * bn * kt <= lane_budget(work):
                cands.add(Tiling(kt, bm, bn))
    return sorted(cands, key=lambda t: (t.k_tile, t.block_m, t.block_n))


def tune(M: int, N: int, K: int, n_bits: int,
         cache: Optional[TuningCache] = None, *, trunc: Optional[int] = None,
         cap: int = 48, repeat: int = 2, save: bool = True) -> Tiling:
    """Measure the candidate grid for one GEMM bucket and persist the
    winner. Candidates come from the *real* shape; measurement shapes
    are capped (CPU interpret mode cannot time a million-row GEMM; the
    bucket key still records the real shape class, and relative tile
    timings transfer because the kernel's per-tile work is
    shape-independent) — but each proxy dim is grown to cover the
    largest candidate block, so a candidate is never silently clipped
    by the proxy and a measured entry can never lose to the heuristic
    it was supposed to improve on (the heuristic is in the race)."""
    import numpy as np

    import jax.numpy as jnp

    from .matmul import olm_matmul

    cands = _candidates(M, N, K, n_bits, trunc)
    Mc = min(M, max(cap, 2 * max(c.block_m for c in cands)))
    Nc = min(N, max(cap, 2 * max(c.block_n for c in cands)))
    Kc = min(K, max(cap, max(c.k_tile for c in cands)))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((Mc, Kc)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((Kc, Nc)).astype(np.float32))
    best, best_us = None, float("inf")
    for cand in cands:
        fn = lambda: np.asarray(olm_matmul(
            x, w, n_bits=n_bits, trunc=trunc, use_pallas=True,
            quantize="kernel", k_tile=cand.k_tile, block_m=cand.block_m,
            block_n=cand.block_n))
        fn()   # compile
        us = float("inf")
        for _ in range(repeat):
            t0 = time.perf_counter()
            fn()
            us = min(us, (time.perf_counter() - t0) * 1e6)
        if us < best_us:
            best, best_us = cand, us
    cache = cache or default_cache()
    cache.store(M, N, K, n_bits, best, source="measured", trunc=trunc,
                us=best_us)
    if save:
        cache.save()
    return best


# ---------------------------------------------------------------- CLI


def _launch_gemms() -> list[tuple[int, int, int]]:
    """Representative (M, N, K) GEMMs for the launch/shapes.py shape
    set: per shape case, M is the flattened row count its kind feeds
    the dot engine (decode = global_batch rows, train/prefill =
    batch*seq), crossed with the canonical projection shapes of a
    transformer block at small/large d_model (attn d->d, MLP d->4d and
    4d->d)."""
    from repro.launch.shapes import SHAPES

    gemms = set()
    for case in SHAPES.values():
        rows = (case.global_batch if case.kind == "decode"
                else case.global_batch * case.seq_len)
        for d in (1024, 4096):
            gemms.update({(rows, d, d), (rows, 4 * d, d), (rows, d, 4 * d)})
    return sorted(gemms)


def main(argv: Optional[list[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="populate the olm matmul tiling cache for the "
                    "launch/shapes.py shape set")
    ap.add_argument("--cache", default=None,
                    help=f"cache path (default {DEFAULT_CACHE_PATH} or "
                         "$REPRO_TUNING_CACHE)")
    ap.add_argument("--cap", type=int, default=48,
                    help="per-dim measurement cap (CPU-friendly proxies)")
    ap.add_argument("--heuristic-only", action="store_true",
                    help="record heuristic tilings without measuring")
    ap.add_argument("--n-bits", default="8,16,24,32",
                    help="comma-separated digit widths to tune; truncated "
                         "modes as n't'p tokens, e.g. 16t12,32t20")
    args = ap.parse_args(argv)
    cache = TuningCache(args.cache)
    widths = []                       # (n_bits, trunc-or-None) pairs
    for tok in args.n_bits.split(","):
        nb, _, tp = tok.strip().partition("t")
        widths.append((int(nb), int(tp) if tp else None))
    gemms = _launch_gemms()
    seen = set()
    for (M, N, K) in gemms:
        for nb, tp in widths:
            key = bucket_key(M, N, K, nb, tp)
            if key in seen:
                continue
            seen.add(key)
            if args.heuristic_only:
                t = heuristic_tiling(M, N, K, nb, tp)
                cache.store(M, N, K, nb, t, source="heuristic", trunc=tp)
                print(f"{key}: heuristic {t.as_dict()}")
            else:
                t = tune(M, N, K, nb, cache, trunc=tp, cap=args.cap,
                         save=False)
                print(f"{key}: measured {t.as_dict()}")
    cache.save()
    print(f"wrote {len(seen)} entries to {cache.path}")


if __name__ == "__main__":
    main()
