"""Grid-tiled Pallas matmul over the fused online inner-product array.

This is the operand-reuse kernel the paper's *minimized interconnect*
claim maps to on a TPU substrate: instead of the front-end broadcasting
digit grids to (M*N, k_tile, n) on the host — the hardware's full
operand fan-out — the kernel runs on an (M_tiles, N_tiles, K_tiles)
grid whose BlockSpecs deliver each x-row operand once per output-row
tile and each w-column operand once per output-column tile:

  x digits (M, T, kt, n): block (block_m, 1, kt, n) at (i, kk) — the
      index map ignores the N grid axis, so a row grid is fetched once
      per (row tile, K tile) and reused across all block_n columns.
  w digits (N, T, kt, n): block (block_n, 1, kt, n) at (j, kk) —
      symmetric reuse across all block_m rows.

Per grid step the body broadcasts the two small blocks *in VMEM* to the
(block_m * block_n) lane batch, runs the shared lane_tree datapath
(K-lane multiplier recurrence + online adder tree — the same function
the batched dot kernel uses), stream-decodes in-kernel
(kernels/common.decode_stream_inkernel), folds the 2^L tree scale and
the per-(row, tile) quantization scales, and accumulates into the
resident (block_m, block_n) float32 output block across the K grid
dimension (innermost, so the block stays live — no Python K loop, no
host-side partial-product round trips).

Two operand formats share that datapath:

  olm_matmul_pallas — the host-quantize path: operands arrive as
      pre-expanded signed-digit grids, so every BlockSpec load moves
      kt*n int32 digits per row/column. This is the oracle-adjacent
      reference kernel.
  olm_matmul_fused_pallas — the quantize-in-kernel path: BlockSpecs
      load *raw float32 tiles* ((block, 1, kt) — n x fewer elements
      than the digit grids they encode) and the kernel prologue runs
      kernels/common.sd_quantize_inkernel, the exact function the host
      front-end uses, before the same lane_tree body. This is the
      software analog of the paper's interconnect discipline: recoding
      happens *inside* the array, so only narrow operands cross HBM
      (matmul.digit_traffic's fused_bytes column measures the cut).

Digit-grid traffic per K tile drops from 2*M*N*kt*n elements to
(M*N_tiles + N*M_tiles)*kt*n — a harmonic-mean reuse factor
2/(1/block_m + 1/block_n) >= min(block_m, block_n) — and the fused
path divides the per-grid element count by n again, measured by
matmul.digit_traffic and asserted in tests/test_olm_matmul_grid.py.

Bit-identity across all three paths (fused kernel, host-quantize
kernel, broadcast oracle) holds by construction: the quantizer is one
shared function (sd_quantize_inkernel — bitcast pow2 scales, no
transcendentals, two-limb digit extraction at n = 32), the digit
arithmetic is lane_tree (bit-exact vs the int64 recurrence), the
stream decode is exact — plain f32 contraction inside the n + 2L <= 24
window, the two-limb wide decode (kernels/common.decode_policy) up to
48 digits for the n = 24/32 modes, both order-invariant and both
rounding the exact dyadic value to float32 at most once — every scale
multiply is by a power of two (exact), and the K-tile accumulation
order matches the oracle's loop.

interpret=True on the CPU container; flip to False on a real TPU
(ROADMAP open item: validate the Mosaic lowering of the 4-D operand
blocks + per-level tree reshapes there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import OnlinePrecision
from repro.kernels.common import (checked_schedule, decode_policy,
                                  decode_stream_inkernel,
                                  decode_stream_wide_inkernel,
                                  pad_to_multiple, sd_quantize_inkernel)
from .kernel import lane_tree
from .ref import tree_levels

__all__ = ["olm_matmul_pallas", "olm_matmul_fused_pallas",
           "tile_update", "fused_tile_update",
           "matmul_block_shapes", "fused_matmul_block_shapes"]


def tile_update(xd, sx, wd, sw, sched, *, n, delta, t, S, L, wide):
    """Shared tile body: fan the per-row / per-column digit grids out to
    the (bm * bn) PE lane batch inside VMEM, run lane_tree, decode, and
    fold the exact 2^L tree scale and the pow2 quantization scales.
    Returns the (bm, bn) float32 increment for the resident output block.
    Both operand formats (pre-quantized grids, raw float tiles) end up
    here, so their arithmetic is identical instruction for instruction.
    `wide` (static, from kernels/common.decode_policy on the n + 2L
    stream length) selects the two-limb wide stream decode for the
    n = 24/32 modes — bit-identical to the host oracle's
    int64-or-two-limb decode.

    Pure jnp function (no Refs): olmlint's jaxpr contract checker traces
    it in isolation per (mode, tiling) and the kernels below call it.
    """
    bm, kt, _ = xd.shape
    bn = wd.shape[0]
    # Operand reuse happens here: each row/column grid was loaded (or,
    # on the fused path, produced from its float tile) once and is
    # fanned out to the (bm * bn) PE lane batch inside VMEM.
    xg = jnp.broadcast_to(xd[:, None], (bm, bn, kt, n)).reshape(bm * bn, kt, n)
    wg = jnp.broadcast_to(wd[None, :], (bm, bn, kt, n)).reshape(bm * bn, kt, n)
    z = lane_tree(xg, wg, sched, n=n, delta=delta, t=t, S=S)
    decode = decode_stream_wide_inkernel if wide else decode_stream_inkernel
    val = decode(z) * jnp.float32(1 << L)                   # exact 2^L fold
    scale = sx.reshape(bm, 1) * sw.reshape(1, bn)           # (bm, bn), pow2
    return val.reshape(bm, bn) * scale


def fused_tile_update(xt, wt, sched, *, n, delta, t, S, L, wide):
    """Quantize-in-kernel tile body: signed-digit recoding prologue on
    the raw float32 tiles, then the same tile_update datapath. Returns
    the (bm, bn) float32 increment. Pure jnp function for the same
    reason as tile_update."""
    # The prologue IS the host quantizer (same function, same backend):
    # digits and pow2 scales are bit-identical to sd_quantize on host.
    xd, sx = sd_quantize_inkernel(xt, n=n)   # (bm, kt, n), (bm, 1)
    wd, sw = sd_quantize_inkernel(wt, n=n)
    return tile_update(xd, sx, wd, sw, sched,
                       n=n, delta=delta, t=t, S=S, L=L, wide=wide)


def _kernel(sched_ref, xd_ref, sx_ref, wd_ref, sw_ref, out_ref,
            *, n, delta, t, S, L, wide):
    """One (block_m, block_n) output tile x one K tile, host-quantized
    operands: digit grids cross HBM."""

    @pl.when(pl.program_id(2) == 0)
    def _():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    xd = xd_ref[...][:, 0]     # (block_m, kt, n) int32 digits in {-1,0,1}
    wd = wd_ref[...][:, 0]     # (block_n, kt, n)
    out_ref[...] += tile_update(xd, sx_ref[...], wd, sw_ref[...],
                                sched_ref[...], n=n, delta=delta, t=t,
                                S=S, L=L, wide=wide)


def _fused_kernel(sched_ref, x_ref, w_ref, out_ref,
                  *, n, delta, t, S, L, wide):
    """One (block_m, block_n) output tile x one K tile, quantize fused
    into the prologue: raw float32 tiles cross HBM (n x fewer elements
    than their digit grids) and the signed-digit recoding happens here,
    inside the array — the paper's reduced-interconnect discipline."""

    @pl.when(pl.program_id(2) == 0)
    def _():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    xt = x_ref[...][:, 0]      # (block_m, kt) raw float32 row tile
    wt = w_ref[...][:, 0]      # (block_n, kt) raw float32 column tile
    out_ref[...] += fused_tile_update(xt, wt, sched_ref[...],
                                      n=n, delta=delta, t=t, S=S, L=L,
                                      wide=wide)


def matmul_block_shapes(*, n: int, delta: int, kt: int,
                        bm: int, bn: int) -> dict:
    """Per-grid-step VMEM block table for the host-quantize matmul path:
    name -> (block shape, dtype). Single source for the layout — the
    pallas_call below builds its BlockSpecs from it and the olmlint VMEM
    footprint model (repro.analysis.vmem) sums it against the
    width-aware lane budget, so kernel and analyzer cannot disagree."""
    return {
        "sched": ((n + delta,), jnp.int32),
        "x_digits": ((bm, 1, kt, n), jnp.int32),
        "x_scales": ((bm, 1), jnp.float32),
        "w_digits": ((bn, 1, kt, n), jnp.int32),
        "w_scales": ((bn, 1), jnp.float32),
        "out": ((bm, bn), jnp.float32),
    }


def fused_matmul_block_shapes(*, n: int, delta: int, kt: int,
                              bm: int, bn: int) -> dict:
    """Per-grid-step VMEM block table for the quantize-in-kernel path:
    raw float tiles cross HBM, n x fewer elements than the digit grids
    (plus the in-VMEM digit grids the prologue materializes, which the
    analyzer accounts separately as lane-batch working set)."""
    return {
        "sched": ((n + delta,), jnp.int32),
        "x_tiles": ((bm, 1, kt), jnp.float32),
        "w_tiles": ((bn, 1, kt), jnp.float32),
        "out": ((bm, bn), jnp.float32),
    }


@functools.partial(
    jax.jit,
    static_argnames=("n", "delta", "t", "truncated", "tail_gating",
                     "tail_guard", "block_m", "block_n", "interpret"),
)
def olm_matmul_pallas(
    x_digits: jax.Array,   # (M, T, kt, n) int32 per-K-tile row digit grids
    x_scales: jax.Array,   # (M, T) float32 power-of-two row scales
    w_digits: jax.Array,   # (N, T, kt, n) column digit grids (from w.T)
    w_scales: jax.Array,   # (N, T)
    *,
    n: int,
    delta: int = 3,
    t: int = 2,
    truncated: bool = True,
    tail_gating: bool = True,
    tail_guard: int = 2,
    block_m: int = 8,
    block_n: int = 8,
    interpret: bool = True,  # CPU container: interpret; False on real TPU
) -> jax.Array:
    """Grid-tiled matmul through the fused array; returns (M, N) float32.

    Operands arrive pre-quantized (matmul.py's quantize-and-dispatch
    front-end): per K tile, each x row / w column is an (kt, n) signed-
    digit grid with a power-of-two scale. The float32 accumulator is
    carried across the K grid dimension inside the kernel.
    """
    cfg = OnlinePrecision(n=n, delta=delta, t=t, truncated=truncated,
                          tail_gating=tail_gating, tail_guard=tail_guard)
    sched_np, S = checked_schedule(cfg)
    M, T, kt, n_ = x_digits.shape
    N = w_digits.shape[0]
    if n_ != n:
        raise ValueError(f"operand digit count {n_} != cfg n {n}")
    if w_digits.shape[1:] != (T, kt, n):
        raise ValueError(
            f"w digit grid {w_digits.shape} does not match x grid "
            f"{x_digits.shape} in (K_tiles, k_tile, n)")
    if x_scales.shape != (M, T) or w_scales.shape != (N, T):
        raise ValueError("scale shapes must be (rows, K_tiles)")
    L = tree_levels(kt)
    wide = decode_policy(n + 2 * L) == "wide"
    bm = max(1, min(block_m, M))
    bn = max(1, min(block_n, N))
    xd = pad_to_multiple(x_digits.astype(jnp.int32), bm, 0)
    sx = pad_to_multiple(x_scales.astype(jnp.float32), bm, 0)
    wd = pad_to_multiple(w_digits.astype(jnp.int32), bn, 0)
    sw = pad_to_multiple(w_scales.astype(jnp.float32), bn, 0)
    Mp, Np = xd.shape[0], wd.shape[0]
    grid = (Mp // bm, Np // bn, T)   # K innermost: accumulator stays live
    kern = functools.partial(_kernel, n=n, delta=delta, t=t, S=S, L=L,
                             wide=wide)
    blocks = matmul_block_shapes(n=n, delta=delta, kt=kt, bm=bm, bn=bn)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(blocks["sched"][0], lambda i, j, k: (0,)),
            pl.BlockSpec(blocks["x_digits"][0],
                         lambda i, j, k: (i, k, 0, 0)),  # x rows: j-blind
            pl.BlockSpec(blocks["x_scales"][0], lambda i, j, k: (i, k)),
            pl.BlockSpec(blocks["w_digits"][0],
                         lambda i, j, k: (j, k, 0, 0)),  # w cols: i-blind
            pl.BlockSpec(blocks["w_scales"][0], lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec(blocks["out"][0], lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(sched_np), xd, sx, wd, sw)
    return out[:M, :N]


@functools.partial(
    jax.jit,
    static_argnames=("n", "delta", "t", "truncated", "tail_gating",
                     "tail_guard", "block_m", "block_n", "interpret"),
)
def olm_matmul_fused_pallas(
    x_tiles: jax.Array,    # (M, T, kt) float32 raw per-K-tile row slices
    w_tiles: jax.Array,    # (N, T, kt) raw column slices (from w.T)
    *,
    n: int,
    delta: int = 3,
    t: int = 2,
    truncated: bool = True,
    tail_gating: bool = True,
    tail_guard: int = 2,
    block_m: int = 8,
    block_n: int = 8,
    interpret: bool = True,  # CPU container: interpret; False on real TPU
) -> jax.Array:
    """Grid-tiled matmul with signed-digit quantization fused into the
    kernel prologue; returns (M, N) float32.

    Operands arrive as *raw float32 tiles* — no digit grids ever exist
    on the host or in HBM. Each BlockSpec load moves a (block, 1, kt)
    float tile (n x fewer elements than the (block, 1, kt, n) digit
    grids olm_matmul_pallas ships); the prologue runs
    kernels/common.sd_quantize_inkernel — the very function the host
    front-end uses — so digits, scales, and therefore the output are
    bit-identical to the host-quantize path and the broadcast oracle.
    """
    cfg = OnlinePrecision(n=n, delta=delta, t=t, truncated=truncated,
                          tail_gating=tail_gating, tail_guard=tail_guard)
    sched_np, S = checked_schedule(cfg)
    M, T, kt = x_tiles.shape
    N = w_tiles.shape[0]
    if w_tiles.shape[1:] != (T, kt):
        raise ValueError(
            f"w tiles {w_tiles.shape} do not match x tiles "
            f"{x_tiles.shape} in (K_tiles, k_tile)")
    L = tree_levels(kt)
    wide = decode_policy(n + 2 * L) == "wide"
    bm = max(1, min(block_m, M))
    bn = max(1, min(block_n, N))
    # Zero-padding rows is benign: all-zero tiles quantize in-kernel to
    # all-zero digit grids with scale 1.0 (pow2_scale's zero guard).
    xt = pad_to_multiple(x_tiles.astype(jnp.float32), bm, 0)
    wt = pad_to_multiple(w_tiles.astype(jnp.float32), bn, 0)
    Mp, Np = xt.shape[0], wt.shape[0]
    grid = (Mp // bm, Np // bn, T)   # K innermost: accumulator stays live
    kern = functools.partial(_fused_kernel, n=n, delta=delta, t=t, S=S, L=L,
                             wide=wide)
    blocks = fused_matmul_block_shapes(n=n, delta=delta, kt=kt, bm=bm, bn=bn)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(blocks["sched"][0], lambda i, j, k: (0,)),
            pl.BlockSpec(blocks["x_tiles"][0],
                         lambda i, j, k: (i, k, 0)),   # x float rows: j-blind
            pl.BlockSpec(blocks["w_tiles"][0],
                         lambda i, j, k: (j, k, 0)),   # w float cols: i-blind
        ],
        out_specs=pl.BlockSpec(blocks["out"][0], lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(sched_np), xt, wt)
    return out[:M, :N]
