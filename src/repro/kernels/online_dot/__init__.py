"""Fused online inner-product array (multiplier lanes + online adder tree).

The batched, digit-serial form of the paper's target workload: K radix-2
online multipliers stream product digits into a balanced tree of online
adders (delta_add = 2 per level), emitting the dot-product digit stream
without ever materializing a full-precision product. Bit-exact against the
core/inner_product.py oracle.

  kernel.py        — fused Pallas kernel (int32 datapath, Fig. 7
                     schedule) + the shared lane_tree datapath body
  matmul_kernel.py — grid-tiled Pallas matmul: (M_tiles, N_tiles,
                     K_tiles) grid, operand digit grids loaded once per
                     output tile (the paper's minimized-interconnect
                     discipline), in-kernel stream decode and f32
                     K-accumulation
  ref.py           — int64 jnp reference + the vectorized adder-tree
                     recurrence
  ops.py           — digit-grid dispatch (int32-fit check, block_b
                     tiling)
  matmul.py        — quantize-and-dispatch float matmul front-end
                     (shared K-tiling/quantize plumbing, grid kernel or
                     broadcast oracle) behind DotEngine's olm8/olm16
                     modes
"""
from .matmul import olm_error_bound, olm_matmul, olm_matmul_ref
from .ops import online_dot, dot_scale_log2, dot_stream_length

__all__ = ["online_dot", "dot_scale_log2", "dot_stream_length",
           "olm_matmul", "olm_matmul_ref", "olm_error_bound"]
