"""Float matmul lowered through the fused online inner-product array.

This is the front-end that turns the paper's centerpiece kernel into a
model numerics engine: a float tile ``x (M, K) @ w (K, N)`` is computed
the way the hardware array would —

  1. K is tiled into chunks of ``k_tile`` lanes (the array width; the
     adder tree reduces one chunk per kernel call).
  2. Each chunk's rows of x and columns of w are quantized to n-digit
     MSDF signed-digit grids with power-of-two per-row scales
     (kernels/common.sd_quantize — shared with the tpmm plane quantizer).
  3. The fused kernel (K multiplier lanes + online adder tree, one Pallas
     call) emits the dot-product digit stream sum_i x_i y_i / 2^L per
     (m, n) output element; no full-precision product intermediate exists.
  4. Streams are decoded (kernels/common.decode_stream_jnp), the 2^L tree
     scale and the quantization scales are folded out, and chunk partial
     products accumulate in float32.

``olm_matmul_ref`` is the pure-jnp oracle: identical tiling / quantize /
decode plumbing around the int64 reference recurrence instead of the
Pallas kernel. Because the kernel is bit-exact against that recurrence
(tests/test_kernel_online_dot.py) and every other stage is shared, the
two paths produce bit-identical float32 outputs — the property
DotEngine's olm modes are tested against.

Error vs the exact float matmul is bounded by ``olm_error_bound``: per
lane, quantization contributes <= 1 ulp at 2^-n (two round-to-nearest
operands) and the truncated multiplier <= 1.1 ulp (G=2 tail, measured
<= 0.93); the adder tree is exact. The documented per-lane ledger is
ULP_PER_LANE = 3.1 output ulp at the tile's power-of-two scale product,
matching the k * (2 + 1.1) * 2^-n bound the array example quotes.

Known cost: operand digit grids are broadcast to (M*N, k_tile, n), i.e.
x digits are replicated N times and w digits M times. That is exactly
the hardware's operand fan-out to the PE array; doing the reuse inside
the kernel (one x-grid load per output row) is a ROADMAP item.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.precision import OnlinePrecision
from repro.kernels.common import (decode_stream_jnp, pad_to_multiple,
                                  pow2_scale, resolve_use_pallas, sd_quantize)
from .kernel import online_dot_pallas
from .ref import online_dot_batch_ref, tree_levels

__all__ = ["olm_matmul", "olm_matmul_ref", "olm_error_bound",
           "DEFAULT_K_TILE", "ULP_PER_LANE"]

# Array width: lanes reduced by one adder tree. 16 keeps the digit grids
# VMEM-friendly and the stream length n + 2*ceil(log2 16) = n + 8 within
# float32-exact decode range for n <= 16.
DEFAULT_K_TILE = 16

# Documented per-lane error ledger in output ulp at 2^-n (see module
# docstring): 2 quantized operands + 1.1 multiplier truncation, rounded
# up. Tests hold olm_matmul to k * ULP_PER_LANE * 2^-n per tile.
ULP_PER_LANE = 3.1


def _olm_cfg(n_bits: int) -> OnlinePrecision:
    """The paper's array configuration at this output precision (delta=3,
    t=2, Eq. 8 truncation, G=2 tail — configs/olm_array.ARRAY_PRECISIONS)."""
    return OnlinePrecision(n=n_bits)


def _tiles(K: int, k_tile: int) -> tuple[int, int]:
    """(lanes per tile, tile count) for a K-deep contraction."""
    kt = min(k_tile, K)
    return kt, -(-K // kt)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "k_tile", "use_pallas", "block_b",
                     "interpret"),
)
def olm_matmul(
    x: jax.Array,  # (M, K) float
    w: jax.Array,  # (K, N) float
    *,
    n_bits: int = 16,
    k_tile: int = DEFAULT_K_TILE,
    use_pallas: bool | None = None,
    block_b: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Matmul through the fused online inner-product array; (M, N) float32.

    use_pallas: True = fused Pallas kernel, False = int64 jnp reference,
    None = Pallas iff the config fits the int32 datapath. Both paths are
    bit-identical (shared quantize/decode, bit-exact kernel).

    Raises ValueError when n_bits + 2*ceil(log2 k_tile) exceeds the
    24-digit float32-exact decode window (see decode_stream_jnp).
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: x (M,{K}) @ w ({K2},N)")
    cfg = _olm_cfg(n_bits)
    use = resolve_use_pallas(cfg, use_pallas)
    kw = dict(n=cfg.n, delta=cfg.delta, t=cfg.t, truncated=cfg.truncated,
              tail_gating=cfg.tail_gating, tail_guard=cfg.tail_guard)
    kt, n_tiles = _tiles(K, k_tile)
    L = tree_levels(kt)
    if n_bits + 2 * L > 24:
        raise ValueError(
            f"stream length {n_bits + 2 * L} (n_bits={n_bits}, "
            f"k_tile={kt}) exceeds the float32-exact decode window of "
            "24 digits; lower k_tile or n_bits (n=24/32 lowering is a "
            "ROADMAP item)")
    xp = pad_to_multiple(x.astype(jnp.float32), kt, 1)
    wp = pad_to_multiple(w.astype(jnp.float32), kt, 0)
    acc = jnp.zeros((M, N), jnp.float32)
    for ti in range(n_tiles):
        xt = xp[:, ti * kt:(ti + 1) * kt]              # (M, kt)
        wt = wp[ti * kt:(ti + 1) * kt, :]              # (kt, N)
        xd, sx = sd_quantize(xt, n=n_bits, axis=1)     # (M, kt, n), (M, 1)
        wd, sw = sd_quantize(wt.T, n=n_bits, axis=1)   # (N, kt, n), (N, 1)
        xg = jnp.broadcast_to(xd[:, None], (M, N, kt, n_bits))
        yg = jnp.broadcast_to(wd[None, :], (M, N, kt, n_bits))
        xg = xg.reshape(M * N, kt, n_bits)
        yg = yg.reshape(M * N, kt, n_bits)
        if use:
            xg = pad_to_multiple(xg, block_b, 0)
            yg = pad_to_multiple(yg, block_b, 0)
            z = online_dot_pallas(xg, yg, block_b=block_b,
                                  interpret=interpret, **kw)[:M * N]
        else:
            z = online_dot_batch_ref(xg, yg, **kw)
        val = decode_stream_jnp(z) * jnp.float32(1 << L)   # (M*N,)
        acc = acc + val.reshape(M, N) * (sx * sw.reshape(1, N))
    return acc


def olm_matmul_ref(x: jax.Array, w: jax.Array, *, n_bits: int = 16,
                   k_tile: int = DEFAULT_K_TILE) -> jax.Array:
    """Pure-jnp oracle for `olm_matmul`: the same tiling, quantization and
    stream-decode plumbing around the int64 reference recurrence. The
    Pallas path must match this bit-for-bit (tests/test_dot_engine.py)."""
    return olm_matmul(x, w, n_bits=n_bits, k_tile=k_tile, use_pallas=False)


def olm_error_bound(x: jax.Array, w: jax.Array, *, n_bits: int = 16,
                    k_tile: int = DEFAULT_K_TILE) -> jax.Array:
    """Documented per-element bound on |olm_matmul(x, w) - x @ w|, (M, N)
    float32: per K-tile, k lanes each contribute <= ULP_PER_LANE output
    ulp at 2^-n times the tile's power-of-two scale product."""
    M, K = x.shape
    _, N = w.shape
    kt, n_tiles = _tiles(K, k_tile)
    xp = pad_to_multiple(x.astype(jnp.float32), kt, 1)
    wp = pad_to_multiple(w.astype(jnp.float32), kt, 0)
    bound = jnp.zeros((M, N), jnp.float32)
    per_lane = jnp.float32(ULP_PER_LANE * 2.0 ** -n_bits)
    for ti in range(n_tiles):
        sx = pow2_scale(xp[:, ti * kt:(ti + 1) * kt], 1)        # (M, 1)
        sw = pow2_scale(wp[ti * kt:(ti + 1) * kt, :].T, 1)      # (N, 1)
        bound = bound + kt * per_lane * (sx * sw.reshape(1, N))
    return bound
