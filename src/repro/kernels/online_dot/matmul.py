"""Float matmul lowered through the fused online inner-product array.

This is the front-end that turns the paper's centerpiece kernel into a
model numerics engine: a float tile ``x (M, K) @ w (K, N)`` is computed
the way the hardware array would —

  1. K is tiled into chunks of ``k_tile`` lanes (the array width; the
     adder tree reduces one chunk per kernel step).
  2. Each chunk's rows of x and columns of w are quantized to n-digit
     MSDF signed-digit grids with power-of-two per-row scales
     (kernels/common.sd_quantize — shared with the tpmm plane quantizer).
  3. The grid-tiled Pallas kernel (matmul_kernel.olm_matmul_pallas) runs
     the K multiplier lanes + online adder tree per (m, n) output
     element on an (M_tiles, N_tiles, K_tiles) grid: each x-row digit
     grid is loaded once per output-row tile and each w-column grid once
     per output-column tile — the paper's minimized-interconnect operand
     discipline — then stream-decodes, folds the 2^L tree scale and the
     quantization scales, and carries the float32 accumulator across the
     K grid dimension. No full-precision product intermediate exists.

This module is deliberately just quantize-and-dispatch: shared tiling /
padding / quantization (one `_tile_plan` + `_quantize_tiles` pair used
by matmul, oracle and error bound alike), then either the grid kernel
or the pure-jnp oracle.

``olm_matmul_ref`` is that oracle: identical quantize plumbing around
the int64 reference recurrence, with operand grids broadcast to
(M*N, k_tile, n) — the hardware's full operand fan-out, kept as the
operand-traffic baseline (`digit_traffic` quantifies the reuse factor
the grid kernel wins back). Because the kernel's digit arithmetic is
bit-exact against the recurrence, the stream decode is exact — plain
f32 contraction inside the n + 2L <= 24 window, and the wide decode
(int64 accumulator under x64, two-limb f32 otherwise; both round the
exact dyadic value to f32 once, RN-even) up to 48 digits for the
n = 24/32 modes — every scale multiply is a power of two, and both
paths accumulate K tiles in the same order, the two paths produce
bit-identical float32 outputs — the property DotEngine's olm modes are
tested against.

Error vs the exact float matmul is bounded by ``olm_error_bound``: per
lane, quantization contributes <= 1 ulp at 2^-n (two round-to-nearest
operands) and the truncated multiplier <= 1.1 ulp (G=2 tail, measured
<= 0.93); the adder tree is exact. The documented per-lane ledger is
ULP_PER_LANE = 3.1 output ulp at the tile's power-of-two scale product,
matching the k * (2 + 1.1) * 2^-n bound the array example quotes.

Mesh-sharded GEMMs go through `matmul_sharded.olm_matmul_sharded`, a
shard_map wrapper that runs this same front-end per device shard —
output-sharded partitions ("m"/"n") are bit-identical to this module;
the K-sharded partition psums f32 partials within olm_error_bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.precision import OnlinePrecision, truncation_schedule
from repro.kernels.common import (decode_policy, decode_stream_jnp,
                                  decode_stream_wide_jnp, int64_enabled,
                                  pad_to_multiple, pow2_scale,
                                  resolve_use_pallas, sd_quantize)
from .matmul_kernel import olm_matmul_fused_pallas, olm_matmul_pallas
from .ref import online_dot_batch_ref, oracle_needs_x64, tree_levels

__all__ = ["olm_matmul", "olm_matmul_ref", "olm_error_bound",
           "digit_traffic", "DEFAULT_K_TILE", "DEFAULT_BLOCK_M",
           "DEFAULT_BLOCK_N", "DEFAULT_QUANTIZE", "ULP_PER_LANE",
           "WIDE_DECODE_ULP"]

# Array width: lanes reduced by one adder tree. 16 keeps the digit grids
# VMEM-friendly and the stream length n + 2*ceil(log2 16) = n + 8 within
# float32-exact decode range for n <= 16.
DEFAULT_K_TILE = 16

# Output-tile shape of the grid kernel. 8x8 keeps the in-kernel lane
# batch (block_m * block_n * k_tile = 1024 lanes) VMEM-friendly while
# already buying an 8x digit-grid reuse factor.
DEFAULT_BLOCK_M = 8
DEFAULT_BLOCK_N = 8

# Where signed-digit quantization runs on the Pallas path: "kernel"
# fuses it into the kernel prologue so raw float tiles are what cross
# HBM (n x fewer operand elements than digit grids — the paper's
# recode-inside-the-array interconnect discipline); "host" quantizes up
# front and ships pre-expanded digit grids (the PR-3 path, kept as the
# near-oracle reference). Both are bit-identical (shared quantizer).
DEFAULT_QUANTIZE = "kernel"

# Documented per-lane error ledger in output ulp at 2^-n (see module
# docstring): 2 quantized operands + 1.1 multiplier truncation, rounded
# up. Tests hold olm_matmul to k * ULP_PER_LANE * 2^-n per tile.
ULP_PER_LANE = 3.1

# Extra per-lane budget (in absolute units at the tile scale product)
# for the wide-decode modes (stream > 24 digits, i.e. n = 24/32): the
# wide decode rounds the exact dyadic tile value to float32 once —
# <= 0.5 ulp of |val * 2^L| <= kt/4, i.e. <= kt * 2^-26 per tile — and
# each of the T float32 K-tile accumulations rounds once more, each
# <= 2^-24 * |acc| <= 2^-26 * kt * sum_t(sx_t * sw_t). Both fold into
# one (T + 1) * 2^-26 per-lane term (olm_error_bound). Narrow modes
# (n <= 16) keep the historical quantization-only bound: their decode
# is exact and the same accumulation rounding is invisible under the
# ~256x larger 2^-n quantization term.
WIDE_DECODE_ULP = 2.0 ** -26


def _olm_cfg(n_bits: int) -> OnlinePrecision:
    """The paper's array configuration at this output precision (delta=3,
    t=2, Eq. 8 truncation, G=2 tail — configs/olm_array.ARRAY_PRECISIONS)."""
    return OnlinePrecision(n=n_bits)


def _tile_plan(x: jax.Array, w: jax.Array, k_tile: int
               ) -> tuple[int, int, jax.Array, jax.Array]:
    """The one K-tiling decision, shared by matmul, oracle and error
    bound: (lanes per tile kt, tile count T, x zero-padded to (M, T*kt),
    w.T zero-padded to (N, T*kt)). Zero padding is benign end to end —
    padded lanes quantize to all-zero digit grids (pow2_scale guards
    all-zero slices) and contribute exact zeros."""
    K = x.shape[1]
    kt = min(k_tile, K)
    n_tiles = -(-K // kt)
    xp = pad_to_multiple(x.astype(jnp.float32), kt, 1)
    wp = pad_to_multiple(w.astype(jnp.float32), kt, 0)
    return kt, n_tiles, xp, wp.T


def _quantize_tiles(rows: jax.Array, kt: int, n_tiles: int, n_bits: int
                    ) -> tuple[jax.Array, jax.Array]:
    """Quantize (R, T*kt) rows to per-K-tile signed-digit grids:
    digits (R, T, kt, n_bits) int32, scales (R, T) float32 pow2."""
    R = rows.shape[0]
    d, s = sd_quantize(rows.reshape(R, n_tiles, kt), n=n_bits, axis=2)
    return d, s[..., 0]


def _decode_plan(n_bits: int, kt: int) -> tuple[int, bool]:
    """(tree levels L, wide?) for an n_bits-digit stream reduced over a
    kt-lane tree — the dtype-aware decode policy: streams inside the
    24-digit window decode on the plain f32 path (n = 8/16 at default
    tiling, bit-for-bit the historical behavior); wider streams (the
    n = 24/32 modes, or a deep tree at n = 16) take the exact wide
    decode (int64 accumulator under x64, two-limb f32 otherwise).
    Raises past the 48-digit wide window (kernels/common.decode_policy),
    before any path is dispatched."""
    L = tree_levels(kt)
    try:
        policy = decode_policy(n_bits + 2 * L)
    except ValueError as e:
        raise ValueError(f"n_bits={n_bits}, k_tile={kt}: {e}") from None
    return L, policy == "wide"


def _broadcast_ref(xd, sx, wd, sw, L, wide, **kw) -> jax.Array:
    """Pure-jnp oracle body: per K tile, broadcast the digit grids to the
    full (M*N, kt, n) operand fan-out — exactly what the hardware delivers
    to the PE array, and the traffic baseline the grid kernel beats —
    run the int64 reference recurrence, decode (wide path for > 24-digit
    streams) and accumulate in f32 in the same K-tile order as the
    kernel's grid."""
    M, T, kt, n = xd.shape
    N = wd.shape[0]
    decode = decode_stream_wide_jnp if wide else decode_stream_jnp
    acc = jnp.zeros((M, N), jnp.float32)
    for ti in range(T):
        xg = jnp.broadcast_to(xd[:, ti][:, None], (M, N, kt, n))
        wg = jnp.broadcast_to(wd[:, ti][None, :], (M, N, kt, n))
        z = online_dot_batch_ref(xg.reshape(M * N, kt, n),
                                 wg.reshape(M * N, kt, n), **kw)
        val = decode(z) * jnp.float32(1 << L)               # (M*N,)
        acc = acc + val.reshape(M, N) * (sx[:, ti:ti + 1] *
                                         sw[:, ti].reshape(1, N))
    return acc


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "k_tile", "use", "block_m", "block_n",
                     "quantize", "interpret"),
)
def _olm_matmul_impl(
    x: jax.Array,  # (M, K) float
    w: jax.Array,  # (K, N) float
    *,
    n_bits: int,
    k_tile: int,
    use: bool,
    block_m: int,
    block_n: int,
    quantize: str,
    interpret: bool,
) -> jax.Array:
    """The jitted matmul body behind `olm_matmul`, dispatch already
    resolved on the host (use: Pallas vs broadcast oracle; the wrapper
    also owns the x64 scoping the n = 32 oracle needs)."""
    M, K = x.shape
    N = w.shape[1]
    cfg = _olm_cfg(n_bits)
    kw = dict(n=cfg.n, delta=cfg.delta, t=cfg.t, truncated=cfg.truncated,
              tail_gating=cfg.tail_gating, tail_guard=cfg.tail_guard)
    kt, n_tiles, xp, wpT = _tile_plan(x, w, k_tile)
    L, wide = _decode_plan(n_bits, kt)
    if use and quantize == "kernel":
        # No digit grid ever exists outside the kernel: ship the raw
        # (rows, T, kt) float tiles and recode in the prologue.
        return olm_matmul_fused_pallas(
            xp.reshape(M, n_tiles, kt), wpT.reshape(N, n_tiles, kt),
            block_m=block_m, block_n=block_n, interpret=interpret, **kw)
    xd, sx = _quantize_tiles(xp, kt, n_tiles, n_bits)    # (M,T,kt,n), (M,T)
    wd, sw = _quantize_tiles(wpT, kt, n_tiles, n_bits)   # (N,T,kt,n), (N,T)
    if use:
        return olm_matmul_pallas(xd, sx, wd, sw, block_m=block_m,
                                 block_n=block_n, interpret=interpret, **kw)
    return _broadcast_ref(xd, sx, wd, sw, L, wide, **kw)


def olm_matmul(
    x: jax.Array,  # (M, K) float
    w: jax.Array,  # (K, N) float
    *,
    n_bits: int = 16,
    k_tile: int = DEFAULT_K_TILE,
    trunc: int | None = None,
    use_pallas: bool | None = None,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    quantize: str = DEFAULT_QUANTIZE,
    interpret: bool = True,
) -> jax.Array:
    """Matmul through the fused online inner-product array; (M, N) float32.

    trunc=p selects the truncated working-precision family `olm{n}t{p}`
    (core.precision.truncation_schedule): the whole array runs at p < n
    working digits — operands quantized to p-digit grids, p + delta
    recurrence iterations, a (k, p) live digit buffer, and a p/n cut in
    digit operand bytes on the grid path — trading a bounded accuracy
    loss (olm_error_bound's truncation term) for throughput. trunc=None
    (default) is the full-precision mode, bit-for-bit the historical
    behavior.

    use_pallas: True = grid-tiled Pallas kernel, False = int64 jnp
    broadcast oracle, None = Pallas iff the config fits the int32
    datapath. quantize selects where the Pallas path recodes operands:
    "kernel" (default) fuses sd_quantize into the kernel prologue so
    raw float tiles cross HBM; "host" ships pre-expanded digit grids
    (the reference grid path). All three paths are bit-identical
    (one shared quantizer, bit-exact digit arithmetic, order-exact
    decode and accumulation — on the wide decode path of the n = 24/32
    modes the int64-or-two-limb decode rounds the exact tile value to
    f32 once, identically on every path and x64 setting).
    block_m/block_n tile the output on the Pallas path (ignored by the
    oracle, which models the full operand fan-out).

    This host wrapper resolves dispatch, then scopes the call under
    repro.compat.enable_x64 when the selected path needs real int64
    and x64 is off: the broadcast oracle's full-width multiplier
    recurrence at n = 32 (F + 3 = 38 bits — ref.oracle_needs_x64).
    The Pallas paths never need the scope (Eq.8-truncated int32
    datapath + two-limb quantize/decode).

    Raises ValueError when n_bits + 2*ceil(log2 k_tile) exceeds the
    48-digit wide exact decode window (kernels/common.decode_policy);
    streams of 25..48 digits transparently use the wide decode.
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: x (M,{K}) @ w ({K2},N)")
    if quantize not in ("kernel", "host"):
        raise ValueError(f"quantize must be 'kernel' or 'host', "
                         f"got {quantize!r}")
    if trunc is not None:
        # Everything downstream — quantizer, kernel, decode, error
        # behavior — is the p-digit array; n_bits only names the family.
        truncation_schedule(n_bits, trunc)     # validates delta+1 <= p < n
        n_bits = trunc
    cfg = _olm_cfg(n_bits)
    use = resolve_use_pallas(cfg, use_pallas)
    _decode_plan(n_bits, min(k_tile, K))     # refuse unservable streams early
    call = functools.partial(
        _olm_matmul_impl, x, w, n_bits=n_bits, k_tile=k_tile, use=use,
        block_m=block_m, block_n=block_n, quantize=quantize,
        interpret=interpret)
    if not use and oracle_needs_x64(cfg.n, cfg.delta) and not int64_enabled():
        if isinstance(x, jax.core.Tracer) or isinstance(w, jax.core.Tracer):
            # Flipping the x64 config mid-trace corrupts the enclosing
            # trace's loop-carry dtypes (observed on jax 0.4.x): the
            # scope is only safe around an eager entry point. The
            # Pallas paths (use_pallas=True/None) never need it — only
            # the n = 32 oracle's full-width recurrence does.
            raise ValueError(
                f"the n_bits={n_bits} broadcast-oracle path needs int64 "
                "but was called inside an already-traced computation: "
                "wrap the outer jit call in repro.compat.enable_x64(), "
                "or use the Pallas path (use_pallas=None/True), whose "
                "Eq.8-truncated datapath fits int32")
        from repro.compat import enable_x64
        with enable_x64():
            return call()
    return call()


def olm_matmul_ref(x: jax.Array, w: jax.Array, *, n_bits: int = 16,
                   k_tile: int = DEFAULT_K_TILE,
                   trunc: int | None = None) -> jax.Array:
    """Pure-jnp oracle for `olm_matmul`: the same tiling, quantization and
    stream-decode plumbing around the int64 reference recurrence, with
    the full (M*N, kt, n) operand broadcast. The Pallas grid kernel must
    match this bit-for-bit (tests/test_dot_engine.py,
    tests/test_olm_matmul_grid.py)."""
    return olm_matmul(x, w, n_bits=n_bits, k_tile=k_tile, trunc=trunc,
                      use_pallas=False)


def olm_error_bound(x: jax.Array, w: jax.Array, *, n_bits: int = 16,
                    k_tile: int = DEFAULT_K_TILE,
                    trunc: int | None = None) -> jax.Array:
    """Documented per-element bound on |olm_matmul(x, w) - x @ w|, (M, N)
    float32: per K-tile, k lanes each contribute <= ULP_PER_LANE output
    ulp at 2^-n times the tile's power-of-two scale product. On the wide
    decode path (stream > 24 digits — the n = 24/32 modes) the bound
    adds (T + 1) * WIDE_DECODE_ULP per lane: one exact-value-to-f32
    decode rounding per K tile plus T accumulator roundings, each
    <= kt * 2^-26 at the tile scale product (see WIDE_DECODE_ULP).

    trunc=p (the `olm{n}t{p}` family) adds the truncation term: the
    per-lane ledger becomes ULP_PER_LANE * (2^-n + 2^-p). The array
    actually runs at p working digits, so its true error is within
    ULP_PER_LANE * 2^-p per lane — strictly inside this sum — and the
    wide-decode term is decided on the p-digit stream (olm32t16's
    16 + 2L <= 24 stream comes back onto the exact plain-f32 path,
    dropping the wide term entirely)."""
    kt, n_tiles, xp, wpT = _tile_plan(x, w, k_tile)
    M, N = xp.shape[0], wpT.shape[0]
    sx = pow2_scale(xp.reshape(M, n_tiles, kt), 2)[..., 0]    # (M, T)
    sw = pow2_scale(wpT.reshape(N, n_tiles, kt), 2)[..., 0]   # (N, T)
    work = n_bits if trunc is None else trunc
    _, wide = _decode_plan(work, kt)
    per_lane = ULP_PER_LANE * 2.0 ** -n_bits
    if trunc is not None:
        per_lane += ULP_PER_LANE * 2.0 ** -trunc
    if wide:
        per_lane += (n_tiles + 1) * WIDE_DECODE_ULP
    return kt * jnp.float32(per_lane) * jnp.einsum("mt,nt->mn", sx, sw)


def digit_traffic(M: int, N: int, K: int, *, n_bits: int = 16,
                  k_tile: int = DEFAULT_K_TILE,
                  trunc: int | None = None,
                  block_m: int = DEFAULT_BLOCK_M,
                  block_n: int = DEFAULT_BLOCK_N) -> dict:
    """Operand traffic ledger for one (M, K) @ (K, N) matmul, in
    elements (4 bytes each — int32 digits or float32 tiles) delivered
    to the compute body.

    broadcast: the oracle/front-end fan-out — both digit grids
      replicated to (M*N, kt, n) per K tile, i.e. x digits N times and
      w digits M times.
    grid: the host-quantize grid kernel's BlockSpec loads — each x-row
      digit grid once per (row tile, K tile) and each w-column grid
      once per (column tile, K tile); reuse = broadcast / grid, the
      harmonic mean 2/(1/block_m + 1/block_n) for even tilings
      (>= min(block_m, block_n), and exactly min/2 x in the most
      lopsided case).
    fused: the quantize-in-kernel path — the same BlockSpec reuse
      pattern, but each load is a raw (block, kt) *float tile* rather
      than its (block, kt, n) digit-grid expansion, so element counts
      drop by n_bits x again: fused_elems = grid_elems / n_bits, and
      fused_reuse = broadcast / fused = n_bits * grid reuse. This is
      what the paper's recode-inside-the-array interconnect saving
      looks like in HBM bytes.

    Per output tile the grid path materializes block_m + block_n
    operand grids where broadcast materializes block_m * block_n of
    each; summed over tiles that is M*N_tiles + N*M_tiles — linear in
    M + N only when the block covers the whole output, O(M*N / reuse)
    under fixed blocks (tests assert both regimes).

    trunc=p (the `olm{n}t{p}` family): operand grids are p digits deep
    instead of n, so every digit-grid column shrinks by exactly p/n —
    the operand-byte floor tools/check_bench.py gates — while the fused
    path's raw float tiles are width-independent (fused_vs_grid == p).
    """
    if trunc is not None and not 0 < trunc < n_bits:
        raise ValueError(f"trunc must satisfy 0 < trunc < n_bits={n_bits}; "
                         f"got {trunc}")
    work = n_bits if trunc is None else trunc   # digits actually streamed
    kt = min(k_tile, K)
    n_tiles = -(-K // kt)
    bm = max(1, min(block_m, M))
    bn = max(1, min(block_n, N))
    m_tiles = -(-M // bm)
    n_out_tiles = -(-N // bn)
    per_grid = kt * work                        # one row/column digit grid
    per_tile = kt                               # one raw float row/column
    loads = m_tiles * bm * n_out_tiles + n_out_tiles * bn * m_tiles
    broadcast = 2 * M * N * per_grid * n_tiles
    grid = loads * per_grid * n_tiles
    fused = loads * per_tile * n_tiles
    return {
        "broadcast_elems": broadcast,
        "grid_elems": grid,
        "fused_elems": fused,
        "broadcast_bytes": 4 * broadcast,
        "grid_bytes": 4 * grid,
        "fused_bytes": 4 * fused,
        "reuse": broadcast / grid,
        "fused_reuse": broadcast / fused,
        "fused_vs_grid": grid / fused,          # == work digits (p or n)
    }
