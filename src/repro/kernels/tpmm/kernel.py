"""Pallas TPU kernel: truncated-precision digit-plane matmul (tpmm).

TPU-native adaptation of the paper's truncated working precision
(DESIGN.md §2): operands are signed radix-2^b digit planes (int8); the
product accumulates plane-pair matmuls MSD-first on the MXU and *stops*
at the significance cutoff derived from paper Eq. 8 — plane pairs whose
weight cannot influence the result's top digits are never computed,
exactly as the paper never builds bit-slices beyond p. For D planes the
full product needs D^2 pair-matmuls; the truncated one needs only the
pairs with da + db < Lmax ~ (D^2 + D)/2 of them, a 30-45% MXU-op saving
at the same delivered output precision — the area/power saving of the
paper transposed to systolic-array occupancy.

Tiling: grid (M/bm, N/bn, K/bk); K is the innermost (sequential) axis so
each (i, j) output tile accumulates across k steps in VMEM scratch. The
plane loop is statically unrolled inside the kernel (D <= 8). Block shapes
default to MXU-aligned (128, 128) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import kept_levels

__all__ = ["tpmm_pallas"]


def _kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref, *,
            n_planes, plane_bits, lmax, k_steps):
    """Accumulate plane-pair partial products for one (bm, bn) tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MSD-first static plane-pair loop, truncated at significance lmax.
    # acc holds sum_L 2^(-b(L+2)) * intacc_L in float32; integer pair
    # accumulation within one (da, db) dot stays int32-exact.
    acc = acc_ref[...]
    for L in range(lmax):
        lacc = None
        for da in range(min(L + 1, n_planes)):
            db = L - da
            if db < 0 or db >= n_planes:
                continue
            prod = jax.lax.dot_general(
                a_ref[da, :, :].astype(jnp.int32),
                b_ref[db, :, :].astype(jnp.int32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            lacc = prod if lacc is None else lacc + prod
        if lacc is None:
            continue
        w = jnp.float32(2.0 ** (-plane_bits * (L + 2)))
        acc = acc + lacc.astype(jnp.float32) * w
    acc_ref[...] = acc

    @pl.when(k == k_steps - 1)
    def _finish():
        o_ref[...] = acc_ref[...] * sa_ref[...] * sb_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "plane_bits", "mode",
                     "block_m", "block_n", "block_k", "interpret"),
)
def tpmm_pallas(
    a_planes: jax.Array,  # (D, M, K) int8
    b_planes: jax.Array,  # (D, K, N) int8
    a_scale: jax.Array,   # (M, 1) float32
    b_scale: jax.Array,   # (1, N) float32
    *,
    n_bits: int,
    plane_bits: int = 4,
    mode: str = "nbit",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,  # CPU container: interpret; False on real TPU
) -> jax.Array:
    """Truncated-precision digit-plane matmul; returns (M, N) float32."""
    D, M, K = a_planes.shape
    _, K2, N = b_planes.shape
    assert K == K2 and b_planes.shape[0] == D
    if M % block_m or N % block_n or K % block_k:
        raise ValueError(
            f"shape ({M},{K},{N}) not divisible by blocks "
            f"({block_m},{block_k},{block_n})")
    lmax = kept_levels(n_bits, plane_bits, mode=mode)
    grid = (M // block_m, N // block_n, K // block_k)
    kern = functools.partial(
        _kernel, n_planes=D, plane_bits=plane_bits, lmax=lmax,
        k_steps=grid[2])
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((D, block_m, block_k), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((D, block_k, block_n), lambda i, j, k: (0, k, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        # float32 accumulator tile, persistent across the sequential K axis
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a_planes, b_planes, a_scale, b_scale)
