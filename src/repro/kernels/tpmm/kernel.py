"""Pallas TPU kernel: truncated-precision digit-plane matmul (tpmm).

TPU-native adaptation of the paper's truncated working precision
(DESIGN.md §2): operands are signed radix-2^b digit planes (int8); the
product accumulates plane-pair matmuls MSD-first on the MXU and *stops*
at the significance cutoff derived from paper Eq. 8 — plane pairs whose
weight cannot influence the result's top digits are never computed,
exactly as the paper never builds bit-slices beyond p. For D planes the
full product needs D^2 pair-matmuls; the truncated one needs only the
pairs with da + db < Lmax ~ (D^2 + D)/2 of them, a 30-45% MXU-op saving
at the same delivered output precision — the area/power saving of the
paper transposed to systolic-array occupancy.

Tiling: grid (M/bm, N/bn, K/bk); K is the innermost (sequential) axis so
each (i, j) output tile accumulates across k steps in VMEM scratch. The
plane loop is statically unrolled inside the kernel (D <= 8). Block shapes
default to MXU-aligned (128, 128) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import kept_levels

__all__ = ["tpmm_pallas", "plane_accumulate", "tpmm_block_shapes"]


def plane_accumulate(a_block, b_block, acc, *, n_planes, plane_bits, lmax):
    """MSD-first truncated plane-pair accumulation for one (bm, bn) tile.

    Pure jnp function (no Refs): olmlint's jaxpr contract checker traces
    it in isolation and the kernel below calls it. The dot_general here
    is the one grandfathered MXU baseline site (AST-lint suppression
    baseline): plane-pair products are the paper's bit-slice partial
    products mapped onto the MXU, not a bypass of DotEngine routing.

    Args:
      a_block: (D, bm, bk) int8 digit planes; b_block: (D, bk, bn).
      acc: (bm, bn) float32 running accumulator.
    Returns the updated (bm, bn) float32 accumulator.
    """
    # Truncated at significance lmax: acc holds
    # sum_L 2^(-b(L+2)) * intacc_L in float32; integer pair accumulation
    # within one (da, db) dot stays int32-exact.
    for L in range(lmax):
        lacc = None
        for da in range(min(L + 1, n_planes)):
            db = L - da
            if db < 0 or db >= n_planes:
                continue
            prod = jax.lax.dot_general(
                a_block[da, :, :].astype(jnp.int32),
                b_block[db, :, :].astype(jnp.int32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            lacc = prod if lacc is None else lacc + prod
        if lacc is None:
            continue
        w = jnp.float32(2.0 ** (-plane_bits * (L + 2)))
        acc = acc + lacc.astype(jnp.float32) * w
    return acc


def _kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref, *,
            n_planes, plane_bits, lmax, k_steps):
    """Accumulate plane-pair partial products for one (bm, bn) tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] = plane_accumulate(
        a_ref[...], b_ref[...], acc_ref[...],
        n_planes=n_planes, plane_bits=plane_bits, lmax=lmax)

    @pl.when(k == k_steps - 1)
    def _finish():
        o_ref[...] = acc_ref[...] * sa_ref[...] * sb_ref[...]


def tpmm_block_shapes(*, n_planes: int, block_m: int, block_n: int,
                      block_k: int) -> dict:
    """Per-grid-step VMEM block table: name -> (block shape, dtype),
    including the float32 scratch accumulator. Single source for the
    layout — the pallas_call below builds its BlockSpecs/scratch from it
    and the olmlint VMEM footprint model (repro.analysis.vmem) sums it."""
    return {
        "a_planes": ((n_planes, block_m, block_k), jnp.int8),
        "b_planes": ((n_planes, block_k, block_n), jnp.int8),
        "a_scale": ((block_m, 1), jnp.float32),
        "b_scale": ((1, block_n), jnp.float32),
        "out": ((block_m, block_n), jnp.float32),
        "acc_scratch": ((block_m, block_n), jnp.float32),
    }


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "plane_bits", "mode",
                     "block_m", "block_n", "block_k", "interpret"),
)
def tpmm_pallas(
    a_planes: jax.Array,  # (D, M, K) int8
    b_planes: jax.Array,  # (D, K, N) int8
    a_scale: jax.Array,   # (M, 1) float32
    b_scale: jax.Array,   # (1, N) float32
    *,
    n_bits: int,
    plane_bits: int = 4,
    mode: str = "nbit",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,  # CPU container: interpret; False on real TPU
) -> jax.Array:
    """Truncated-precision digit-plane matmul; returns (M, N) float32."""
    D, M, K = a_planes.shape
    _, K2, N = b_planes.shape
    assert K == K2 and b_planes.shape[0] == D
    if M % block_m or N % block_n or K % block_k:
        raise ValueError(
            f"shape ({M},{K},{N}) not divisible by blocks "
            f"({block_m},{block_k},{block_n})")
    lmax = kept_levels(n_bits, plane_bits, mode=mode)
    grid = (M // block_m, N // block_n, K // block_k)
    kern = functools.partial(
        _kernel, n_planes=D, plane_bits=plane_bits, lmax=lmax,
        k_steps=grid[2])
    blocks = tpmm_block_shapes(n_planes=D, block_m=block_m,
                               block_n=block_n, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(blocks["a_planes"][0], lambda i, j, k: (0, i, k)),
            pl.BlockSpec(blocks["b_planes"][0], lambda i, j, k: (0, k, j)),
            pl.BlockSpec(blocks["a_scale"][0], lambda i, j, k: (i, 0)),
            pl.BlockSpec(blocks["b_scale"][0], lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec(blocks["out"][0], lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        # float32 accumulator tile, persistent across the sequential K axis
        scratch_shapes=[pltpu.VMEM(*blocks["acc_scratch"])],
        interpret=interpret,
    )(a_planes, b_planes, a_scale, b_scale)
