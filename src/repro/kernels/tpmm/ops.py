"""Public jit'd wrapper for the truncated-precision matmul.

`tpmm(a, b, n_bits)` quantizes float operands into digit planes and runs
the truncated plane-pair matmul (Pallas kernel or jnp oracle). DotEngine
exposes it as the `tpmm8` / `tpmm16` numerics modes. Quantizer range and
block-divisibility guards live in quantize.py / kernel.py (single home
each); this wrapper only pads and dispatches.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.common import pad_to_multiple
from .kernel import tpmm_pallas
from .quantize import plane_decompose
from .ref import kept_levels, num_planes_for, tpmm_ref

__all__ = ["tpmm", "tpmm_cost_model"]


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "plane_bits", "mode", "use_pallas",
                     "block_m", "block_n", "block_k", "interpret"),
)
def tpmm(
    a: jax.Array,  # (M, K) float
    b: jax.Array,  # (K, N) float
    *,
    n_bits: int = 16,
    plane_bits: int = 4,
    mode: str = "nbit",
    use_pallas: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Truncated-precision matmul of float operands; returns (M, N) f32.

    Result carries ~n_bits of significance per the paper's Eq. 8 truncation
    law while computing only ~(D^2+D)/2 of the D^2 plane products.
    """
    M, K = a.shape
    K2, N = b.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: a (M,{K}) @ b ({K2},N)")
    D = num_planes_for(n_bits, plane_bits)
    ap, sa = plane_decompose(a, num_planes=D, plane_bits=plane_bits, axis=1)
    bp, sb = plane_decompose(b, num_planes=D, plane_bits=plane_bits, axis=0)
    if not use_pallas:
        return tpmm_ref(ap, bp, sa, sb, n_bits=n_bits,
                        plane_bits=plane_bits, mode=mode)
    ap = pad_to_multiple(pad_to_multiple(ap, block_m, 1), block_k, 2)
    bp = pad_to_multiple(pad_to_multiple(bp, block_k, 1), block_n, 2)
    sa_p = pad_to_multiple(sa.reshape(M, 1), block_m, 0)
    sb_p = pad_to_multiple(sb.reshape(1, N), block_n, 1)
    out = tpmm_pallas(
        ap, bp, sa_p, sb_p, n_bits=n_bits, plane_bits=plane_bits,
        mode=mode, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret)
    return out[:M, :N]


def tpmm_cost_model(n_bits: int = 16, plane_bits: int = 4,
                    mode: str = "nbit") -> dict:
    """MXU-op accounting: full vs truncated plane-pair counts (the paper's
    area/power saving transposed to systolic-array occupancy)."""
    D = num_planes_for(n_bits, plane_bits)
    lmax = kept_levels(n_bits, plane_bits, mode=mode)
    full = D * D
    kept = sum(
        1 for L in range(lmax) for da in range(D) if 0 <= L - da < D
    )
    return {
        "planes": D,
        "levels_kept": lmax,
        "pair_matmuls_full": full,
        "pair_matmuls_truncated": kept,
        "mxu_savings_pct": 100.0 * (1 - kept / full),
    }
