"""Pure-jnp oracle for the truncated-precision digit-plane matmul.

Computes exactly what the Pallas kernel computes: integer plane-pair
matmuls accumulated in int32, keeping only plane pairs whose total
significance level L = da + db is below the Eq.8-derived cutoff, then one
float32 scale-and-sum. Used for bitwise kernel validation; `tpmm_error`
additionally bounds the truncation error against the exact float matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.precision import reduced_precision

__all__ = ["kept_levels", "num_planes_for", "tpmm_ref"]


def num_planes_for(n_bits: int, plane_bits: int) -> int:
    """Planes needed to carry n_bits of operand significance."""
    return -(-n_bits // plane_bits)


def kept_levels(n_bits: int, plane_bits: int, *, mode: str = "nbit") -> int:
    """Number of significance levels L = da+db kept in the product.

    mode="full": all 2D-1 levels (the exact 2n-bit product).
    mode="nbit": L <= D-1 — the paper's headline semantics transposed to
      plane space: an n-bit-accurate product from the triangular half
      (~(D^2+D)/2 of D^2) of the plane pairs; dropped levels contribute
      < ~1 ulp at 2^-n. This is the default truncation.
    mode="eq8": aggressive cutoff at the Eq. 8 residual width
      p = ceil((2n + delta + t)/3): keep L <= ceil(p/b) - 1. Delivers
      ~p-bit products at even fewer MXU ops; use when the consumer
      tolerates reduced precision (e.g. early fwd layers).
    """
    D = num_planes_for(n_bits, plane_bits)
    if mode == "full":
        return 2 * D - 1
    if mode == "nbit":
        return D
    if mode == "eq8":
        p = reduced_precision(n_bits)
        return min(max(-(-p // plane_bits) - 1, 1), 2 * D - 1)
    raise ValueError(f"unknown tpmm mode {mode!r}")


@functools.partial(
    jax.jit, static_argnames=("n_bits", "plane_bits", "mode"))
def tpmm_ref(
    a_planes: jax.Array,  # (D, M, K) int8
    b_planes: jax.Array,  # (D, K, N) int8
    a_scale: jax.Array,   # (M, 1) float32
    b_scale: jax.Array,   # (1, N) float32
    *,
    n_bits: int,
    plane_bits: int = 4,
    mode: str = "nbit",
) -> jax.Array:
    """Oracle matmul over digit planes; returns (M, N) float32."""
    D = a_planes.shape[0]
    Lmax = kept_levels(n_bits, plane_bits, mode=mode)
    out = None
    for L in range(min(Lmax, 2 * D - 1)):
        acc = None
        for da in range(min(L + 1, D)):
            db = L - da
            if db < 0 or db >= D:
                continue
            prod = jax.lax.dot_general(
                a_planes[da].astype(jnp.int32),
                b_planes[db].astype(jnp.int32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            acc = prod if acc is None else acc + prod
        if acc is None:
            continue
        w = jnp.float32(2.0 ** (-plane_bits * (L + 2)))
        term = acc.astype(jnp.float32) * w
        out = term if out is None else out + term
    assert out is not None
    return out * a_scale * b_scale
