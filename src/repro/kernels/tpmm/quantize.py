"""Signed digit-plane decomposition for the truncated-precision matmul.

Maps the paper's radix-2 MSDF digit representation onto MXU-friendly
radix-2^b planes: a tensor row is scaled into (-1, 1) by a power-of-two
scale, then split into D balanced base-2^b digits (MSD plane first), each
an int8 plane. Exactly:

    a = scale * sum_{d=0}^{D-1} plane_d * 2^(-b*(d+1)),   plane_d in [-B/2, B/2]

with B = 2^b. Power-of-two scales keep the decomposition bit-exact, like
the SD representation in the hardware design.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import pow2_scale

__all__ = ["plane_decompose", "plane_reconstruct"]


@functools.partial(jax.jit, static_argnames=("num_planes", "plane_bits", "axis"))
def plane_decompose(
    a: jax.Array, *, num_planes: int, plane_bits: int = 4, axis: int = -1
) -> tuple[jax.Array, jax.Array]:
    """Decompose `a` (float) into signed int8 digit planes along new axis 0.

    Returns:
      planes: (D, *a.shape) int8, MSD plane first (balanced digits).
      scale:  a.shape with `axis` reduced to 1; power-of-two, float32.
    """
    if plane_bits < 2 or plane_bits > 7:
        raise ValueError("plane_bits must be in [2, 7] for int8 planes")
    if plane_bits * num_planes > 30:
        raise ValueError(
            f"plane_bits*num_planes = {plane_bits * num_planes} overflows "
            "the int32 quantizer scale (max 30); n_bits > 28 operand "
            "significance exceeds float32 inputs' 24-bit mantissa anyway")
    B = 1 << plane_bits
    D = num_planes
    scale = pow2_scale(a, axis)
    u = (a / scale).astype(jnp.float32)
    v = jnp.round(u * (B ** D)).astype(jnp.int32)  # |v| <= B^D / 2
    planes = []
    for _ in range(D):
        # Balanced digit extraction LSD-first, digits in [-B/2, B/2]
        # (symmetric, like the SD digit set): round-to-nearest carry with
        # ties toward zero so both extremes +-B/2 are representable and
        # |v| <= B^D/2 never overflows (covered range is (B/2)*sum B^k).
        q = jnp.sign(v) * ((jnp.abs(v) + B // 2 - 1) // B)
        r = v - B * q
        planes.append(r.astype(jnp.int8))
        v = q
    planes = jnp.stack(planes[::-1], axis=0)  # MSD first
    return planes, scale


@functools.partial(jax.jit, static_argnames=("plane_bits",))
def plane_reconstruct(planes: jax.Array, scale: jax.Array, *, plane_bits: int = 4):
    """Inverse of plane_decompose (float32)."""
    D = planes.shape[0]
    w = jnp.exp2(-plane_bits * jnp.arange(1, D + 1, dtype=jnp.float32))
    return scale * jnp.tensordot(w, planes.astype(jnp.float32), axes=(0, 0))
