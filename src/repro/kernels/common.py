"""Shared dispatch and decoding helpers for the digit-serial kernel families.

`online_mul`, `online_dot`, and `tpmm` all make the same three decisions:
does the configuration fit the Pallas int32 datapath, how to pad operands
to the kernel's block tiling, and how to decode digit matrices back to
host integers/floats. This module is the single home for that logic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import OnlinePrecision

__all__ = [
    "fits_int32",
    "pad_to_multiple",
    "decode_digits",
    "decode_stream",
]


def fits_int32(cfg: OnlinePrecision) -> bool:
    """True when the Fig. 7 truncation schedule keeps every architectural
    quantity within the Pallas int32 datapath (max T(j) + 3 <= 31 bits:
    the deepest live slice plus the +-2 residual/selection headroom)."""
    from repro.kernels.online_mul.ref import schedule_arrays
    return int(schedule_arrays(cfg).max()) + 3 <= 31


def pad_to_multiple(x: jax.Array, mult: int, axis: int) -> jax.Array:
    """Zero-pad `x` along `axis` up to the next multiple of `mult`."""
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def decode_digits(z, n: int) -> np.ndarray:
    """SD digit matrix (..., n) -> integer scaled 2^n (host int64, exact
    for n <= 62). The software form of the hardware's OTFC converter."""
    w = np.int64(1) << np.arange(n - 1, -1, -1, dtype=np.int64)
    return np.asarray(z).astype(np.int64) @ w


def decode_stream(digits) -> np.ndarray:
    """SD digit stream (..., m) -> float64 value sum_i d_i 2^-(i+1).

    Exact for m <= 51 (every partial sum is a dyadic rational whose
    numerator fits the float64 significand).
    """
    d = np.asarray(digits).astype(np.float64)
    w = 0.5 ** np.arange(1, d.shape[-1] + 1)
    return d @ w
