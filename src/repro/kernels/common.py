"""Shared dispatch, quantization and decoding plumbing for the digit-serial
kernel families.

`online_mul`, `online_dot`, and `tpmm` all make the same decisions: does
the configuration fit the Pallas int32 datapath, how to pad operands to
the kernel's block tiling, how to map floats onto signed-digit / digit-
plane grids (power-of-two row scales keep every decomposition bit-exact),
and how to decode digit matrices back to host integers/floats. This
module is the single home for that logic; the per-family `ops.py` files
only choose block shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import OnlinePrecision

__all__ = [
    "fits_int32",
    "checked_schedule",
    "resolve_use_pallas",
    "pad_to_multiple",
    "pow2_scale",
    "sd_quantize",
    "sd_quantize_inkernel",
    "decode_digits",
    "decode_stream",
    "decode_stream_jnp",
    "decode_stream_inkernel",
    "DECODE_WINDOW_F32",
    "DECODE_WINDOW_WIDE",
    "decode_policy",
    "int64_enabled",
    "decode_stream_wide_jnp",
    "decode_stream_wide_inkernel",
]

# Exact stream-decode windows, in digits. Up to DECODE_WINDOW_F32 every
# term d_i 2^-(i+1) and every partial subset sum fits the float32
# significand, so a plain f32 contraction decodes exactly for any
# reduction order (decode_stream_jnp / decode_stream_inkernel). Between
# the two windows the stream still decodes exactly, but only through the
# wide pair below: an int64 accumulator (x64 scope) or a two-limb f32
# split — both round the exact dyadic value to float32 once, to the
# identical bit pattern. Past DECODE_WINDOW_WIDE the low two-limb window
# itself would exceed 24 digits and the decode would silently round, so
# every consumer refuses (decode_policy raises).
DECODE_WINDOW_F32 = 24
DECODE_WINDOW_WIDE = 48


def fits_int32(cfg: OnlinePrecision) -> bool:
    """True when the Fig. 7 truncation schedule keeps every architectural
    quantity within the Pallas int32 datapath — i.e. `checked_schedule`
    (the one home of the threshold) accepts the configuration."""
    try:
        checked_schedule(cfg)
    except ValueError:
        return False
    return True


def checked_schedule(cfg: OnlinePrecision) -> tuple[np.ndarray, int]:
    """(T(j) schedule, datapath scale exponent S) for a Pallas kernel, or
    ValueError when the configuration overflows the int32 datapath
    (max T(j) + 3 <= 31 bits: the deepest live slice plus the +-2
    residual/selection headroom). Every Pallas kernel family guards its
    entry point with this; `fits_int32` is the predicate form."""
    from repro.kernels.online_mul.ref import schedule_arrays
    sched = schedule_arrays(cfg)
    S = int(sched.max())
    if S + 3 > 31:
        raise ValueError(
            f"int32 datapath needs max T(j)+3 <= 31, got {S + 3}; "
            "use the int64 jnp reference for this configuration")
    return sched, S


def resolve_use_pallas(cfg: OnlinePrecision, use_pallas: bool | None) -> bool:
    """The dispatch predicate shared by every digit-serial kernel family:
    run the Pallas kernel iff the caller allows it (None = auto) AND the
    configuration fits the int32 datapath; otherwise the int64 jnp
    reference."""
    fits = fits_int32(cfg)
    if use_pallas is None:
        return fits
    return use_pallas and fits


def pad_to_multiple(x: jax.Array, mult: int, axis: int) -> jax.Array:
    """Zero-pad `x` along `axis` up to the next multiple of `mult`."""
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pow2_scale(a: jax.Array, axis: int) -> jax.Array:
    """Power-of-two scale per slice along `axis` (kept as size 1),
    exactly 2^(ceil(log2 max|a|) + 1) >= 2 * max|a| (equality iff the
    max is itself a power of two), so u = a / scale lies in [-1/2, 1/2]
    with the endpoints closed — consumers must tolerate them. The
    power-of-two constraint makes every downstream digit decomposition
    bit-exact, mirroring the SD representation in the hardware design.

    The exponent is read straight off the float32 bit pattern and the
    scale is built by writing the exponent field back (both via
    bitcast) — no log2/exp2 transcendentals, whose backend-dependent
    ulp wobble would break the bit-identity between the host quantizer
    and its in-kernel twin. That also makes this function legal inside
    a Pallas kernel body (no captured array constants, elementwise ops
    only), which is what lets the fused matmul kernel quantize raw
    float tiles in its prologue. The exponent arithmetic runs on |max|
    clamped to the normal range [2^-126, 2^126]; slices whose max
    exceeds 2^126 are outside the supported domain (their scale 2^128+
    is not a finite float32) and get an inf scale — the same loud
    NaN-downstream failure the pre-bitcast exp2 implementation
    produced there, not a silently saturated wrong value.

    All-zero slices get scale 1.0 (not the 2^-125 the clamp floor
    would give): padding rows/tiles then quantize to all-zero digit
    grids with a benign scale, so padded lanes provably contribute
    exact zeros to any downstream product."""
    amax = jnp.max(jnp.abs(a), axis=axis, keepdims=True)
    bits = jax.lax.bitcast_convert_type(
        jnp.clip(amax, jnp.float32(2.0 ** -126), jnp.float32(2.0 ** 126)),
        jnp.int32)
    e_floor = (bits >> 23) - 127                 # floor(log2) for normals
    e_ceil = jnp.where((bits & 0x7FFFFF) == 0, e_floor, e_floor + 1)
    scale = jax.lax.bitcast_convert_type((e_ceil + 1 + 127) << 23,
                                         jnp.float32)
    scale = jnp.where(amax > jnp.float32(2.0 ** 126),
                      jnp.float32(jnp.inf), scale)
    return jnp.where(amax > 0, scale, jnp.float32(1.0)).astype(jnp.float32)


def sd_quantize_inkernel(a: jax.Array, *, n: int
                         ) -> tuple[jax.Array, jax.Array]:
    """Quantize float slices along the *last* axis to MSDF signed-digit
    grids — the single quantizer implementation, shared verbatim by the
    host front-end (`sd_quantize` wraps it) and the fused matmul
    kernel's prologue, so the two paths are bit-identical by
    construction: same ops, same operands, same backend.

    Legal inside a Pallas TPU kernel body: the digit-position shifts
    come from `broadcasted_iota` (1-D iota does not lower on TPU),
    `pow2_scale` is bitcast-based (no captured array constants, no
    transcendentals), and everything else is elementwise int/float VPU
    work.

    Digit extraction is range-split on `n` (a static Python branch):
    for n <= 31 the rounded magnitude |v| <= 2^(n-1) fits int32 and one
    shift-and-mask reads every bit; at n = 32 the closed quantization
    endpoint u = +-1/2 lands on |v| = 2^31, one past int32, so the
    magnitude is kept in float32 (exact: |v| has at most 24 significant
    bits by construction) and split into two exact 16-bit halves whose
    int32 images are bit-sliced instead. The split needs no int64 and
    no x64 scope, so the quantizer stays kernel-legal and bit-identical
    across backends and x64 settings at every supported width. n > 32
    is refused: a float32 input only carries 24 mantissa bits, so wider
    grids would just encode quantization noise.

    Returns:
      digits: (*a.shape, n) int32 in {-1, 0, 1}, appended digit axis,
        encoding  a ~= scale * sum_i digits_i 2^-i  elementwise with
        |error| <= scale * 2^-(n+1) (round-to-nearest at 2^-n).
      scale: a.shape with the last axis reduced to 1; pow2 float32.
    """
    if n > 32:
        raise ValueError(
            f"sd digit extraction supports n <= 32, got n={n} (float32 "
            "inputs carry 24 mantissa bits; wider grids encode noise)")
    a = a.astype(jnp.float32)
    scale = pow2_scale(a, -1)
    r = jnp.round((a / scale) * jnp.float32(2.0 ** n))  # exact; |r| <= 2^(n-1)
    pos = jax.lax.broadcasted_iota(jnp.int32, (1,) * a.ndim + (n,), a.ndim)
    if n <= 31:
        v = r.astype(jnp.int32)
        sign = jnp.sign(v).astype(jnp.int32)
        bits = (jnp.abs(v)[..., None] >> ((n - 1) - pos)) & 1   # digit 1..n
        return sign[..., None] * bits, scale
    # n = 32: |r| can be 2^31 — split the exact f32 magnitude into two
    # 16-bit halves (both splits exact: A is an integer with <= 24
    # significant bits, A_hi a pow2-scaled floor, the difference
    # representable below 2^16) and bit-slice their int32 images.
    sign = jnp.sign(r).astype(jnp.int32)
    mag = jnp.abs(r)
    hi = jnp.floor(mag * jnp.float32(2.0 ** -16)).astype(jnp.int32)
    lo = (mag - jnp.floor(mag * jnp.float32(2.0 ** -16))
          * jnp.float32(2.0 ** 16)).astype(jnp.int32)
    shift = (n - 1) - pos                                        # 31 .. 0
    bits = jnp.where(shift >= 16,
                     (hi[..., None] >> jnp.maximum(shift - 16, 0)) & 1,
                     (lo[..., None] >> jnp.minimum(shift, 15)) & 1)
    return sign[..., None] * bits, scale


def sd_quantize(a: jax.Array, *, n: int, axis: int = -1
                ) -> tuple[jax.Array, jax.Array]:
    """Quantize float slices to MSDF signed-digit grids (vectorized
    core/sd.frac_to_digits: sign-magnitude binary digits with the sign
    applied to every digit — always a valid SD representation).

    Host-side convenience wrapper over `sd_quantize_inkernel` (the one
    implementation both paths share): moves `axis` last, quantizes,
    moves it back.

    Returns:
      digits: (*a.shape, n) int32 in {-1, 0, 1}, appended digit axis,
        encoding  a ~= scale * sum_i digits_i 2^-i  elementwise with
        |error| <= scale * 2^-(n+1) (round-to-nearest at 2^-n).
      scale: a.shape with `axis` reduced to 1; power-of-two float32.
    """
    ax = axis % a.ndim
    if ax == a.ndim - 1:
        return sd_quantize_inkernel(a, n=n)
    digits, scale = sd_quantize_inkernel(jnp.moveaxis(a, ax, -1), n=n)
    return (jnp.moveaxis(digits, -2, ax),
            jnp.moveaxis(scale, -1, ax))


def decode_digits(z, n: int) -> np.ndarray:
    """SD digit matrix (..., n) -> integer scaled 2^n (host int64, exact
    for n <= 62). The software form of the hardware's OTFC converter."""
    w = np.int64(1) << np.arange(n - 1, -1, -1, dtype=np.int64)
    return np.asarray(z).astype(np.int64) @ w


def decode_stream(digits) -> np.ndarray:
    """SD digit stream (..., m) -> float64 value sum_i d_i 2^-(i+1).

    Exact for m <= 51 (every partial sum is a dyadic rational whose
    numerator fits the float64 significand).
    """
    d = np.asarray(digits).astype(np.float64)
    w = 0.5 ** np.arange(1, d.shape[-1] + 1)
    return d @ w


def _stream_weights(m: int) -> np.ndarray:
    """(m,) float32 position weights 2^-(i+1), built on the host so every
    entry is an *exact* power of two. (Device-side jnp.exp2 is a
    transcendental and lands an ulp off exact powers on some backends —
    enough to break the exact-decode window and with it the bit-identity
    between the matmul kernel and its oracle.)"""
    return np.exp2(-np.arange(1, m + 1, dtype=np.float64)).astype(np.float32)


def decode_stream_jnp(digits: jax.Array) -> jax.Array:
    """Traceable float32 form of `decode_stream`, for decode stages that
    must stay inside jit (the matmul front-end). Exact for stream lengths
    m <= 24: every term d_i 2^-(i+1) and every partial subset sum fits
    the float32 significand, so the result is independent of reduction
    order — both the Pallas and the reference matmul paths decode to
    bit-identical values."""
    w = jnp.asarray(_stream_weights(digits.shape[-1]))
    return digits.astype(jnp.float32) @ w


def decode_policy(m: int) -> str:
    """Which exact decode a stream of `m` digits needs: "f32" (plain f32
    contraction, m <= 24) or "wide" (int64 accumulator / two-limb f32,
    m <= 48). The one home of the per-stream-length decision the matmul
    front-end, both Pallas matmul kernels, and the tiling autotuner all
    share. Raises past the wide window, where even the two-limb split
    would silently round."""
    if m <= DECODE_WINDOW_F32:
        return "f32"
    if m <= DECODE_WINDOW_WIDE:
        return "wide"
    raise ValueError(
        f"stream length {m} exceeds the {DECODE_WINDOW_WIDE}-digit wide "
        f"(two-limb/int64) exact decode window; lower k_tile or n_bits")


def int64_enabled() -> bool:
    """True when int64 survives canonicalization (x64 on, globally or via
    the repro.compat.enable_x64 scope)."""
    return jax.dtypes.canonicalize_dtype(jnp.int64) == jnp.dtype(jnp.int64)


def decode_stream_wide_jnp(digits: jax.Array) -> jax.Array:
    """Exact float32 stream decode past the 24-digit f32 window, for
    streams up to DECODE_WINDOW_WIDE digits (the n = 24/32 matmul modes).

    Two implementations, selected by whether int64 is available, both
    returning the SAME bits: the exact dyadic value sum_i d_i 2^-(i+1)
    rounded to float32 once, round-to-nearest-even.

      * int64 accumulator (x64 scope): the integer 2^m-scaled value is
        accumulated exactly (|sum| < 2^m <= 2^48), converted to f32
        (one RN-even rounding) and rescaled by the exact power 2^-m.
      * two-limb f32 (x64 unavailable): the stream splits at digit 24
        into hi/lo windows whose partial sums are each exact in f32
        (every subset sum fits the 24-bit significand — the same
        argument as decode_stream_jnp, applied per window), and the
        final hi + lo add performs the single RN-even rounding of the
        exact total.

    Because both paths round the identical exact value once with the
    identical rounding rule, results are bit-identical across x64
    settings — tested in tests/test_wide_precision_decode.py — so the
    olm24/olm32 three-path bit-identity holds on every CI leg."""
    m = digits.shape[-1]
    if m > DECODE_WINDOW_WIDE:
        raise ValueError(f"stream length {m} exceeds the wide decode "
                         f"window of {DECODE_WINDOW_WIDE} digits")
    if int64_enabled():
        w = jnp.asarray(np.int64(1) << np.arange(m - 1, -1, -1,
                                                 dtype=np.int64))
        total = digits.astype(jnp.int64) @ w          # exact, |.| < 2^48
        return total.astype(jnp.float32) * jnp.float32(2.0 ** -m)
    w = jnp.asarray(_stream_weights(m))
    d = digits.astype(jnp.float32)
    cut = DECODE_WINDOW_F32
    return d[..., :cut] @ w[:cut] + d[..., cut:] @ w[cut:]


def decode_stream_wide_inkernel(digits: jax.Array) -> jax.Array:
    """`decode_stream_wide_jnp` usable inside a Pallas kernel body: the
    two-limb split built from bitcast-exact pow2 weights (no captured
    array constants, no int64 — kernel-legal on TPU datapaths and
    independent of the x64 setting). Each window's masked sum is exact
    for any reduction order (zeros from the mask are exact), and the
    final hi + lo add is the single RN-even rounding of the exact
    total — bit-identical to both decode_stream_wide_jnp branches."""
    m = digits.shape[-1]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
    w = jax.lax.bitcast_convert_type((126 - pos) << 23, jnp.float32)
    terms = digits.astype(jnp.float32) * w
    # float32-typed zero: a bare 0.0 traces as a weak float64 aval under
    # x64, tripping the kernel-no-int64 (no 64-bit dtypes) contract.
    f0 = jnp.float32(0.0)
    hi = jnp.sum(jnp.where(pos < DECODE_WINDOW_F32, terms, f0), axis=-1)
    lo = jnp.sum(jnp.where(pos < DECODE_WINDOW_F32, f0, terms), axis=-1)
    return hi + lo


def decode_stream_inkernel(digits: jax.Array) -> jax.Array:
    """`decode_stream_jnp` usable inside a Pallas TPU kernel body, where
    captured array constants are not allowed and 1-D iota does not lower:
    the exact pow2 weights 2^-(i+1) are built in-kernel by writing the
    float32 exponent field directly (bitcast of (126 - i) << 23 — exact
    by construction, unlike a device exp2), and the contraction is an
    elementwise multiply + axis sum on the VPU rather than a 1-D matvec.

    Bit-identical to `decode_stream_jnp` for any digit order the compiler
    picks, because within the guarded stream window (m <= 24, digits in
    {-1,0,1}) every term and every partial subset sum is exactly
    representable in float32 — reduction order cannot change the result.
    That exactness is what lets the grid matmul kernel decode in-kernel
    and still match the host-side oracle bit for bit."""
    m = digits.shape[-1]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
    w = jax.lax.bitcast_convert_type((126 - pos) << 23, jnp.float32)
    return jnp.sum(digits.astype(jnp.float32) * w, axis=-1)
