"""JAX version-compatibility shims.

The repo pins no single JAX version; the public API it needs moved between
releases. Every call site goes through this module instead of feature-
detecting inline. Supported matrix (unit-tested on the installed version by
tests/test_kernel_online_dot.py::TestCompat):

===================  =====================  ==============================
capability           jax >= 0.6             jax 0.4.x / 0.5.x fallback
===================  =====================  ==============================
mesh context         ``jax.set_mesh``       ``jax.sharding.use_mesh`` if
                                            present, else the ``Mesh``
                                            object's own context manager
x64 scope            ``jax.enable_x64``     ``jax.experimental.enable_x64``
shard_map            ``jax.shard_map``      ``jax.experimental.shard_map.
                     (check_vma kwarg)      shard_map`` (check_rep kwarg)
AbstractMesh ctor    ``AbstractMesh(sizes,  ``AbstractMesh(((name, size),
                     names)``               ...))`` (0.4.x shape_tuple
                                            positional signature)
===================  =====================  ==============================

Nothing here touches device state at import time.
"""
from __future__ import annotations

import re
from typing import ContextManager, Sequence, Tuple

import jax

__all__ = ["jax_version", "use_mesh", "enable_x64", "make_abstract_mesh",
           "shard_map", "shardings_for"]


def jax_version() -> Tuple[int, ...]:
    """Installed JAX version as a comparable int tuple, e.g. (0, 4, 37)."""
    return tuple(int(p) for p in re.findall(r"\d+", jax.__version__)[:3])


def use_mesh(mesh) -> ContextManager:
    """Context manager making `mesh` the ambient mesh for jit/pjit.

    Maps to ``jax.set_mesh`` (>= 0.6), ``jax.sharding.use_mesh`` (late
    0.4.x / 0.5.x), or the ``Mesh`` context-manager protocol (0.4.x).
    `mesh` must be a concrete ``jax.sharding.Mesh`` on the 0.4.x path.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager on 0.4.x


def enable_x64(enable: bool = True) -> ContextManager:
    """Context manager enabling 64-bit types inside its scope.

    Maps to ``jax.enable_x64`` (>= 0.6) or
    ``jax.experimental.enable_x64`` (0.4.x / 0.5.x).
    """
    if hasattr(jax, "enable_x64"):
        return jax.enable_x64(enable)
    from jax.experimental import enable_x64 as _enable_x64
    return _enable_x64(enable)


def shard_map(f, mesh, in_specs, out_specs):
    """Per-shard-mapped ``f`` across the ``shard_map`` API moves.

    Maps to ``jax.shard_map`` (>= 0.6; ``check_rep`` was renamed
    ``check_vma`` along the way) or ``jax.experimental.shard_map.shard_map``
    (0.4.x / 0.5.x). The replication/varying-manual-axes check is disabled
    on every path: the body closes over ``pallas_call``, which has no
    replication rule on the 0.4.x line, and the olm GEMM out_specs are
    always explicit so the check buys nothing here.
    """
    if hasattr(jax, "shard_map"):
        for kw in ({"check_vma": False}, {"check_rep": False}, {}):
            try:
                return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, **kw)
            except TypeError:
                continue
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def shardings_for(mesh, spec_tree):
    """Resolve a PartitionSpec pytree into jit-acceptable shardings.

    jax >= 0.6 lets bare ``PartitionSpec``s flow into ``jax.jit``'s
    in/out_shardings (resolved against the ambient mesh); 0.4.x requires
    concrete ``Sharding`` objects. Binding each spec to ``mesh`` via
    ``NamedSharding`` is valid on every release, so this shim is
    unconditional. ``None`` leaves (unconstrained/inferred) pass through.

    ``PartitionSpec`` is a tuple subclass on 0.4.x, so the tree map must
    treat it as a leaf explicitly or it would be flattened.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    def resolve(s):
        return NamedSharding(mesh, s) if isinstance(s, PartitionSpec) else s

    return jax.tree_util.tree_map(
        resolve, spec_tree, is_leaf=lambda x: isinstance(x, PartitionSpec))


def make_abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """Construct ``jax.sharding.AbstractMesh`` across the positional-
    signature change: new releases take ``(axis_sizes, axis_names)``;
    0.4.x takes a single ``((name, size), ...)`` shape tuple.
    """
    AbstractMesh = jax.sharding.AbstractMesh
    sizes = tuple(int(s) for s in axis_sizes)
    names = tuple(axis_names)
    if len(sizes) != len(names):
        raise ValueError(f"got {len(sizes)} sizes for {len(names)} names")
    try:
        mesh = AbstractMesh(sizes, names)
        # 0.4.x would silently accept `names` as its axis_types kwarg;
        # reading axis_names back distinguishes the two signatures.
        if tuple(mesh.axis_names) == names:
            return mesh
    except (TypeError, ValueError, AttributeError):
        pass
    return AbstractMesh(tuple(zip(names, sizes)))
