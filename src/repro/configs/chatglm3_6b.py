"""ChatGLM3-6B [arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696, vocab 65024. 2d-RoPE
(rotary on half the head dims), QKV bias.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    qkv_bias=True,
    rope_style="half",
    block_pattern=("attn",),
    sharding_profile="tp",
)
