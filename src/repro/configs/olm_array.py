"""The paper's own configuration: pipelined online-multiplier inner-product
arrays at n = 8/16/24/32 bits (delta=3, t=2, Eq.8 truncation, G=2 tail)."""
from repro.core.precision import OnlinePrecision

ARRAY_PRECISIONS = {n: OnlinePrecision(n=n) for n in (8, 16, 24, 32)}
FULL_PRECISIONS = {
    n: OnlinePrecision(n=n, truncated=False, tail_gating=False)
    for n in (8, 16, 24, 32)
}
