"""The paper's own configuration: pipelined online-multiplier inner-product
arrays at n = 8/16/24/32 bits (delta=3, t=2, Eq.8 truncation, G=2 tail),
plus the DotEngine wiring that lets a model select those arrays as its
matmul numerics (mode "olm8" / "olm16")."""
from repro.core.numerics import DotEngine
from repro.core.precision import OnlinePrecision

ARRAY_PRECISIONS = {n: OnlinePrecision(n=n) for n in (8, 16, 24, 32)}
FULL_PRECISIONS = {
    n: OnlinePrecision(n=n, truncated=False, tail_gating=False)
    for n in (8, 16, 24, 32)
}

# Precisions whose matmul lowering is registered as a DotEngine mode
# (n > 16 streams exceed the float32-exact decode window and the int32
# reference path; they stay digit-grid-API only for now).
MATMUL_MODES = {8: "olm8", 16: "olm16"}

# Grid-kernel tiling for the matmul lowering: k_tile lanes per adder
# tree (the array width; n + 2*ceil(log2 k_tile) must stay inside the
# 24-digit f32-exact decode window), and the (block_m, block_n) output
# tile whose BlockSpecs load each operand digit grid once per tile —
# the reuse factor is ~2/(1/block_m + 1/block_n).
MATMUL_TILING = {"k_tile": 16, "block_m": 8, "block_n": 8}


def engine_for(n_bits: int, **overrides) -> DotEngine:
    """DotEngine running every model GEMM through the n_bits-digit fused
    inner-product array (kernels/online_dot/matmul). The paper-array
    MATMUL_TILING is applied unless overridden (any DotEngine field —
    k_tile, block_m, block_n, use_pallas, interpret — may be)."""
    if n_bits not in MATMUL_MODES:
        raise ValueError(
            f"no olm matmul mode at n_bits={n_bits}; "
            f"available: {sorted(MATMUL_MODES)}")
    return DotEngine(mode=MATMUL_MODES[n_bits],
                     **{**MATMUL_TILING, **overrides})
