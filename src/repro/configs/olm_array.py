"""The paper's own configuration: pipelined online-multiplier inner-product
arrays at n = 8/16/24/32 bits (delta=3, t=2, Eq.8 truncation, G=2 tail),
plus the DotEngine wiring that lets a model select those arrays as its
matmul numerics (modes "olm8" / "olm16" / "olm24" / "olm32")."""
from repro.core.numerics import (TRUNCATED_SPECS, DotEngine, EngineSpec,
                                 resolve_engine)
from repro.core.precision import OnlinePrecision, truncation_schedule

ARRAY_PRECISIONS = {n: OnlinePrecision(n=n) for n in (8, 16, 24, 32)}
FULL_PRECISIONS = {
    n: OnlinePrecision(n=n, truncated=False, tail_gating=False)
    for n in (8, 16, 24, 32)
}

# Every ARRAY_PRECISIONS width is a registered DotEngine matmul mode.
# n = 8/16 streams decode on the exact plain-f32 path; n = 24/32 exceed
# the 24-digit f32 window and take the exact wide decode (int64
# accumulator under x64, two-limb f32 otherwise) — see
# kernels/common.decode_policy and the olm24/olm32 registry entries.
MATMUL_MODES = {8: "olm8", 16: "olm16", 24: "olm24", 32: "olm32"}

# Truncated working-precision tiers (the paper's headline lever): the
# n-digit family run at p < n working digits. Keyed (n, p); the schedule
# each mode actually runs is truncation_schedule(n, p) — the olm{p}
# array — so the quantizer, kernel recurrence, and decode all shrink to
# p digits (a p/n cut in digit operand bytes on the grid path).
TRUNCATED_MODES = {(n, p): f"olm{n}t{p}" for n, p in TRUNCATED_SPECS}
TRUNCATED_PRECISIONS = {
    (n, p): truncation_schedule(n, p) for n, p in TRUNCATED_SPECS
}

# Static grid-kernel tiling for the matmul lowering: k_tile lanes per
# adder tree (the array width; n + 2*ceil(log2 k_tile) must stay inside
# the per-dtype exact decode window — 24 digits plain f32 for n <= 16,
# 48 digits wide decode for n = 24/32), and the (block_m, block_n)
# output tile whose BlockSpecs load each operand once per tile — the
# reuse factor is ~2/(1/block_m + 1/block_n). Since the autotuner
# landed (kernels/online_dot/tuning) this is the explicit-opt-out
# fallback (`engine_for(..., tiling=None)`) and the legacy candidate
# the tuner always considers; `engine_for` defaults to tiling="auto".
MATMUL_TILING = {"k_tile": 16, "block_m": 8, "block_n": 8}


def engine_for(n_bits: int, *, trunc: int | None = None,
               tiling: str | None = "auto", **overrides) -> DotEngine:
    """DotEngine running every model GEMM through the n_bits-digit fused
    inner-product array (kernels/online_dot/matmul).

    trunc=p selects the truncated working-precision tier olm{n}t{p}
    (must be a registered TRUNCATED_MODES pair): the same array family
    run at p working digits, trading bounded extra error (the
    olm_error_bound truncation term) for a p/n cut in digit operand
    bytes and recurrence iterations.

    tiling="auto" (default) resolves (block_m, block_n) per GEMM shape
    through the tiling autotuner — a decode GEMV and a training GEMM
    stop sharing one static 8x8 output tile — while k_tile stays at
    the kernel's numerics default, so auto output is bit-identical to
    the static default; tiling=None pins the static paper-array
    MATMUL_TILING. Any DotEngine field (k_tile, block_m, block_n,
    use_pallas, interpret) may be overridden and wins over the
    autotuner.

    Since the EngineSpec redesign this is a thin shim: it validates the
    (n_bits, trunc) pair against this module's registries (keeping the
    historical error messages), builds an EngineSpec, and resolves it
    through core.numerics.resolve_engine — the one construction path
    every engine now takes."""
    if trunc is not None:
        if (n_bits, trunc) not in TRUNCATED_MODES:
            raise ValueError(
                f"no truncated olm mode at n_bits={n_bits} trunc={trunc}; "
                f"available: {sorted(TRUNCATED_MODES)}")
        mode = TRUNCATED_MODES[(n_bits, trunc)]
    elif n_bits in MATMUL_MODES:
        mode = MATMUL_MODES[n_bits]
    else:
        raise ValueError(
            f"no olm matmul mode at n_bits={n_bits}; "
            f"available: {sorted(MATMUL_MODES)}")
    if tiling not in (None, "auto"):
        raise ValueError(f"tiling must be 'auto' or None, got {tiling!r}")
    base = {"tiling": "auto"} if tiling == "auto" else dict(MATMUL_TILING)
    return resolve_engine(EngineSpec(mode=mode, **{**base, **overrides}))
