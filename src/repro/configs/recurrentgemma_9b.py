"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427; unverified].

38L d_model=4096 16H (MQA kv=1, head_dim 256 per its paper) d_ff=12288,
vocab 256000. RG-LRU + local attention, pattern 2 recurrent : 1 attn
(window 2048): 12 * (rec, rec, attn) + 2 rec remainder = 38 layers.
Attention-free recurrence => long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    sliding_window=2048,
    block_pattern=("rec", "rec", "attn"),
    rnn_width=4096,
    conv_width=4,
    sharding_profile="fsdp_tp",
)
