"""Mamba2-130M [arXiv:2405.21060; unverified].

24L d_model=768, attention-free SSD (state-space duality), ssm_state=128,
expand 2 (d_inner 1536, headdim 64 -> 24 ssm heads), vocab 50280.
O(L) scan => long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=12,          # unused by SSD blocks; kept for head_dim derivation
    n_kv_heads=12,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    conv_width=4,
    block_pattern=("ssm",),
    tie_embeddings=True,
    sharding_profile="tp",
)
