"""SeamlessM4T-medium [arXiv:2308.11596; hf].

Enc-dec: 12 encoder + 12 decoder layers, d_model=1024, 16H MHA (kv=16),
d_ff=4096 (GELU), vocab 256206. The speech frontend is a STUB:
input_specs provides precomputed frame embeddings (B, M, d_model).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    mlp_type="gelu",
    block_pattern=("xdec",),
    n_frontend_tokens=1024,
    sharding_profile="tp",
)
