"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (GQA kv=4) d_ff=1536/expert, vocab 151936,
MoE 128 experts top-8. Assigned-table head_dim = d_model/H = 64 (the HF
checkpoint uses 128; we follow the assigned table — DESIGN.md §5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    n_experts=128,
    experts_per_token=8,
    block_pattern=("attn",),
    sharding_profile="fsdp_tp",
    moe_sharding="ep",
)
