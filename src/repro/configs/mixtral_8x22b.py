"""Mixtral-8x22B [arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff=16384, vocab 32768, MoE 8 experts
top-2, sliding-window attention (4096) => sub-quadratic: long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    block_pattern=("attn",),
    sharding_profile="fsdp_tp",
    moe_sharding="tp",   # 8 experts < 16-way model axis: TP inside experts
)
