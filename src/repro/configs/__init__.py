"""Architecture registry: one module per assigned architecture.

get_config(arch_id)    -> full published config (dry-run only; never
                          allocated on CPU)
smoke_config(arch_id)  -> reduced same-family config for CPU smoke tests
list_archs()           -> all registered ids
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_ARCHS = [
    "qwen3_moe_235b_a22b",
    "mixtral_8x22b",
    "recurrentgemma_9b",
    "chatglm3_6b",
    "qwen1_5_110b",
    "internlm2_1_8b",
    "yi_34b",
    "seamless_m4t_medium",
    "mamba2_130m",
    "llama_3_2_vision_11b",
]

ALIASES = {a.replace("_", "-"): a for a in _ARCHS}
ALIASES.update({
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mixtral-8x22b": "mixtral_8x22b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen1.5-110b": "qwen1_5_110b",
    "internlm2-1.8b": "internlm2_1_8b",
    "yi-34b": "yi_34b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "mamba2-130m": "mamba2_130m",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
})


def list_archs() -> List[str]:
    return list(_ARCHS)


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: small widths/layers/experts/vocab,
    runnable on CPU for one forward/train step."""
    cfg = get_config(arch)
    pat_len = len(cfg.block_pattern)
    n_layers = max(2 * pat_len, pat_len + cfg.n_layers % pat_len)
    upd = dict(
        n_layers=n_layers,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        n_experts=8 if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2),
        capacity_factor=4.0,  # avoid drops in tiny smoke batches
        rnn_width=128 if cfg.rnn_width else None,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=32 if cfg.ssm_state else 64,
        ssm_chunk=8,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        n_frontend_tokens=16 if cfg.n_frontend_tokens else 0,
        sliding_window=16 if cfg.sliding_window else None,
        remat="none",
    )
    return dataclasses.replace(cfg, **upd)
