"""Llama-3.2-Vision-11B [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L text backbone d_model=4096 32H (GQA kv=8) d_ff=14336, vocab 128256,
cross-attention image layers every 5th layer: 8 * (attn x4, cross) = 40.
Vision frontend is a STUB: input_specs provides projected patch
embeddings (B, M, d_model).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    block_pattern=("attn", "attn", "attn", "attn", "cross"),
    n_frontend_tokens=1024,
    sharding_profile="fsdp_tp",
)
