"""Quickstart: the paper's multiplier, digit by digit.

Runs one online multiplication MSDF (watch output digits appear while
input digits are still arriving), the truncated-precision version, a
pipelined inner-product array (paper Table III timing), and the hardware
cost model (paper Table I).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.hwmodel import online_multiplier_cost
from repro.core.inner_product import online_dot_pipelined
from repro.core.online_mul import OnlineMulState, online_multiply
from repro.core.precision import OnlinePrecision, reduced_precision
from repro.core.sd import digits_to_frac, frac_to_digits


def main():
    n = 8
    x, y = 0.40625, -0.7265625
    xd, yd = frac_to_digits(x, n), frac_to_digits(y, n)
    print(f"x = {x} -> digits {xd}")
    print(f"y = {y} -> digits {yd}")

    print(f"\nMSDF execution (n={n}, delta=3, truncated p="
          f"{reduced_precision(n)} of {n} slices):")
    cfg = OnlinePrecision(n=n)
    st = OnlineMulState(cfg)
    step = 0
    while not st.done:
        out = st.step(xd, yd)
        q = step - cfg.delta + 1 + cfg.delta
        in_dig = f"in: x_{q}={xd[q-1] if q <= n else 0:+d}" if q <= n else "in: --"
        out_s = f"out: z={out:+d}" if out is not None else "out: (delay)"
        print(f"  cycle {step:2d}  {in_dig:14s} {out_s:14s} "
              f"live slices: {st.active[-1]}")
        step += 1
    z = digits_to_frac(st.z_digits)
    print(f"product = {z}  (exact {x * y}, error {abs(z - x * y):.2e} "
          f"= {abs(z - x * y) * 2**n:.3f} ulp)")

    # pipelined inner product (the paper's target workload)
    k = 8
    rng = np.random.default_rng(0)
    xs = [frac_to_digits(v, n) for v in rng.uniform(-0.9, 0.9, k)]
    ys = [frac_to_digits(v, n) for v in rng.uniform(-0.9, 0.9, k)]
    r = online_dot_pipelined(xs, ys)
    want = sum(digits_to_frac(a) * digits_to_frac(b) for a, b in zip(xs, ys))
    print(f"\npipelined dot (k={k}): {r.dot_value:.6f} (exact {want:.6f}) "
          f"in {r.cycles} cycles — paper Table III: (n+delta+1)+(k-1) = "
          f"{(n + 3 + 1) + (k - 1)} + adder-tree delay")

    # hardware cost model (paper Table I)
    print("\narea/power model (gate-equivalents, MCNC costs):")
    for nn in (8, 16, 24, 32):
        full = online_multiplier_cost(OnlinePrecision(nn, truncated=False,
                                                      tail_gating=False))
        red = online_multiplier_cost(OnlinePrecision(nn))
        print(f"  n={nn:2d}: area {full.area:8.0f} -> {red.area:8.0f} "
              f"({100 * (1 - red.area / full.area):.1f}% saved), "
              f"latches {full.latches} -> {red.latches}")


if __name__ == "__main__":
    main()
