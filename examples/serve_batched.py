"""Serve a small model with continuous batching (deliverable (b) example).

Runs the same request stream through the paged KV cache (default) and
the contiguous oracle layout, and prints the latency percentiles plus
the KV-residency win of the block-table layout.

  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import main as serve_main


def main():
    common = [
        "--arch", "internlm2_1_8b", "--smoke",
        "--requests", "10", "--slots", "4",
        "--max-new", "12", "--max-len", "96",
    ]
    paged = serve_main(common + ["--kv-layout", "paged",
                                 "--kv-block-size", "8"])
    contig = serve_main(common + ["--kv-layout", "contiguous"])
    print("served", paged["n"], "requests; TTFT p50",
          f"{paged['ttft_p50_s'] * 1e3:.1f} ms, p99",
          f"{paged['ttft_p99_s'] * 1e3:.1f} ms,",
          f"{paged['tokens_per_s']:.1f} tok/s")
    kvp, kvc = paged["kv"], contig["kv"]
    print("KV resident: paged", kvp["kv_bytes_resident"], "B vs contiguous",
          kvc["kv_bytes_resident"], "B",
          f"({kvp['kv_bytes_resident'] / kvc['kv_bytes_resident']:.1%})")


if __name__ == "__main__":
    main()
