"""Serve a small model with continuous batching (deliverable (b) example).

  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import main as serve_main


def main():
    rep = serve_main([
        "--arch", "internlm2_1_8b", "--smoke",
        "--requests", "10", "--slots", "4",
        "--max-new", "12", "--max-len", "96",
    ])
    print("served", rep["n"], "requests; mean TTFT",
          f"{rep['ttft_mean_s'] * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
