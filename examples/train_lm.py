"""Train a ~130M Mamba2 LM for a few hundred steps on synthetic data
(deliverable (b): end-to-end training driver), with checkpoint/restart.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses the real launcher (repro.launch.train) — the same code path as the
production mesh — on the local CPU device, at the full mamba2-130m config
reduced in sequence length only.
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="mamba2_130m")
    ap.add_argument("--smoke", action="store_true",
                    help="use reduced config instead of the full 130M")
    args = ap.parse_args()
    argv = [
        "--arch", args.arch,
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "256",
        "--ckpt-every", "100",
        "--log-every", "10",
    ]
    if args.smoke:
        argv.append("--smoke")
    summary = train_main(argv)
    assert summary["loss_improved"], "loss did not improve over training"
    print("loss improved:", summary["loss_first"], "->", summary["loss_last"])


if __name__ == "__main__":
    main()
