"""The paper's technique as model numerics: truncated-precision matmul
(tpmm) vs exact, on a real transformer layer forward pass.

  PYTHONPATH=src python examples/online_numerics_matmul.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core.numerics import DotEngine
from repro.kernels.tpmm.ops import tpmm, tpmm_cost_model
from repro.models.model import Model


def main():
    # 1) raw op: error/cost tradeoff
    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 512)).astype(np.float32)
    b = rng.standard_normal((512, 256)).astype(np.float32)
    exact = a @ b
    print("tpmm error / MXU-op savings (paper Eq. 8 transposed to planes):")
    for nb in (8, 16, 24):
        got = np.asarray(tpmm(jnp.asarray(a), jnp.asarray(b), n_bits=nb,
                              use_pallas=False))
        rel = np.max(np.abs(got - exact)) / np.abs(exact).max()
        cm = tpmm_cost_model(nb)
        print(f"  n_bits={nb:2d}: rel err {rel:.2e}, "
              f"{cm['pair_matmuls_truncated']}/{cm['pair_matmuls_full']} "
              f"plane-matmuls ({cm['mxu_savings_pct']:.1f}% saved)")

    # 2) whole-model forward under tpmm numerics
    cfg = smoke_config("internlm2_1_8b")
    m_exact = Model(cfg, DotEngine(mode="native"))
    m_tp = Model(cfg, DotEngine(mode="tpmm16", use_pallas=False))
    params = m_exact.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    le, _ = m_exact.forward(params, batch)
    lt, _ = m_tp.forward(params, batch)
    le, lt = np.asarray(le), np.asarray(lt)
    agree = (le.argmax(-1) == lt.argmax(-1)).mean()
    print(f"\nmodel forward, native vs tpmm16 numerics: "
          f"max |dlogit| = {np.abs(le - lt).max():.3f}, "
          f"argmax agreement = {agree * 100:.1f}%")


if __name__ == "__main__":
    main()
