"""The paper's technique as model numerics: truncated-precision matmul
(tpmm) vs exact on a real transformer layer forward pass, and the fused
digit-serial inner-product array (online_dot) computing a matmul tile the
way the paper's PE array would — product digits streaming into an online
adder tree, never a full-precision intermediate.

  PYTHONPATH=src python examples/online_numerics_matmul.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core.numerics import DotEngine
from repro.core.precision import OnlinePrecision
from repro.core.sd import frac_to_digits
from repro.kernels.online_dot.ops import dot_scale_log2, online_dot
from repro.kernels.tpmm.ops import tpmm, tpmm_cost_model
from repro.models.model import Model


def main():
    # 1) raw op: error/cost tradeoff
    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 512)).astype(np.float32)
    b = rng.standard_normal((512, 256)).astype(np.float32)
    exact = a @ b
    print("tpmm error / MXU-op savings (paper Eq. 8 transposed to planes):")
    for nb in (8, 16, 24):
        got = np.asarray(tpmm(jnp.asarray(a), jnp.asarray(b), n_bits=nb,
                              use_pallas=False))
        rel = np.max(np.abs(got - exact)) / np.abs(exact).max()
        cm = tpmm_cost_model(nb)
        print(f"  n_bits={nb:2d}: rel err {rel:.2e}, "
              f"{cm['pair_matmuls_truncated']}/{cm['pair_matmuls_full']} "
              f"plane-matmuls ({cm['mxu_savings_pct']:.1f}% saved)")

    # 2) fused inner-product array: an (M, N) matmul tile as B = M*N
    #    digit-serial dot products of length K, one kernel call
    n, K, M, N = 16, 16, 4, 4
    at = rng.uniform(-0.9, 0.9, (M, K)).astype(np.float64)
    bt = rng.uniform(-0.9, 0.9, (K, N)).astype(np.float64)
    enc = lambda t: np.array([frac_to_digits(float(v), n) for v in t.ravel()],
                             np.int32).reshape(*t.shape, n)
    ad, bd = enc(at), enc(bt.T)
    xg = np.broadcast_to(ad[:, None], (M, N, K, n)).reshape(M * N, K, n)
    yg = np.broadcast_to(bd[None, :], (M, N, K, n)).reshape(M * N, K, n)
    _, dots = online_dot(np.ascontiguousarray(xg), np.ascontiguousarray(yg),
                         OnlinePrecision(n=n), use_pallas=True, block_b=8)
    got = dots.reshape(M, N)
    err = np.abs(got - at @ bt).max()
    print(f"\nonline_dot array: {M}x{N} tile, K={K}, n={n} digits "
          f"(tree scale 2^-{dot_scale_log2(K)} folded out): "
          f"max |err| = {err:.2e} "
          f"(quantize+truncation bound ~{(K * (2 + 1.1)) * 2.0 ** -n:.2e})")

    # 3) whole-model forward under tpmm numerics
    cfg = smoke_config("internlm2_1_8b")
    m_exact = Model(cfg, DotEngine(mode="native"))
    m_tp = Model(cfg, DotEngine(mode="tpmm16", use_pallas=False))
    params = m_exact.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    le, _ = m_exact.forward(params, batch)
    lt, _ = m_tp.forward(params, batch)
    le, lt = np.asarray(le), np.asarray(lt)
    agree = (le.argmax(-1) == lt.argmax(-1)).mean()
    print(f"\nmodel forward, native vs tpmm16 numerics: "
          f"max |dlogit| = {np.abs(le - lt).max():.3f}, "
          f"argmax agreement = {agree * 100:.1f}%")


if __name__ == "__main__":
    main()
