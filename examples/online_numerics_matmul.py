"""The paper's technique as model numerics, selected through one DotEngine
dispatch surface: truncated-precision digit-plane matmul (tpmm) vs exact
on a real transformer layer forward pass, and the fused digit-serial
inner-product array (olm) computing float matmul tiles the way the
paper's PE array would — product digits streaming into an online adder
tree, never a full-precision intermediate.

  PYTHONPATH=src python examples/online_numerics_matmul.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core.numerics import DotEngine
from repro.kernels.online_dot.matmul import (olm_error_bound, olm_matmul,
                                             olm_matmul_ref)
from repro.kernels.tpmm.ops import tpmm, tpmm_cost_model
from repro.models import layers
from repro.models.model import Model


def main():
    # 0) the dispatch surface: every mode is a registered DotMode
    print("DotEngine mode registry (error / cost trade-offs):")
    for m in DotEngine.mode_table():
        print(f"  {m.name:>7}: {m.summary}")
        print(f"  {'':>7}  error: {m.error}; cost: {m.cost}")

    # 1) raw tpmm op: error/cost tradeoff
    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 512)).astype(np.float32)
    b = rng.standard_normal((512, 256)).astype(np.float32)
    exact = a @ b
    print("\ntpmm error / MXU-op savings (paper Eq. 8 transposed to planes):")
    for nb in (8, 16, 24):
        got = np.asarray(tpmm(jnp.asarray(a), jnp.asarray(b), n_bits=nb,
                              use_pallas=False))
        rel = np.max(np.abs(got - exact)) / np.abs(exact).max()
        cm = tpmm_cost_model(nb)
        print(f"  n_bits={nb:2d}: rel err {rel:.2e}, "
              f"{cm['pair_matmuls_truncated']}/{cm['pair_matmuls_full']} "
              f"plane-matmuls ({cm['mxu_savings_pct']:.1f}% saved)")

    # 2) fused inner-product array as a float matmul: the olm front-end
    #    K-tiles and quantizes to signed-digit grids; the grid-tiled
    #    Pallas kernel loads each operand grid once per output tile,
    #    runs the K multiplier lanes + online adder tree per element and
    #    decodes in-kernel — bit-identical to the pure-jnp oracle
    n, M, K, N = 16, 4, 24, 4
    at = rng.standard_normal((M, K)).astype(np.float32)
    bt = rng.standard_normal((K, N)).astype(np.float32)
    got_p = np.asarray(olm_matmul(jnp.asarray(at), jnp.asarray(bt), n_bits=n,
                                  use_pallas=True, block_m=4, block_n=4))
    got_r = np.asarray(olm_matmul_ref(jnp.asarray(at), jnp.asarray(bt),
                                      n_bits=n))
    bound = np.asarray(olm_error_bound(jnp.asarray(at), jnp.asarray(bt),
                                       n_bits=n))
    err = np.abs(got_p - at @ bt)
    print(f"\nolm_matmul: {M}x{K}x{N} tile, n={n} digits: "
          f"pallas == oracle bitwise: {np.array_equal(got_p, got_r)}, "
          f"max |err| = {err.max():.2e} "
          f"(documented bound {bound.max():.2e}, "
          f"{(err / bound).max() * 100:.0f}% used)")

    # 3) end-to-end MLP forward through the array numerics
    cfg = smoke_config("internlm2_1_8b")
    key = jax.random.PRNGKey(0)
    p = layers.mlp_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model),
                          jnp.float32)
    y_native = np.asarray(layers.mlp_apply(p, cfg, x,
                                           DotEngine(mode="native")))
    y_olm = np.asarray(layers.mlp_apply(p, cfg, x, DotEngine(mode="olm16")))
    print(f"\nMLP forward (d={cfg.d_model}, ff={cfg.d_ff}), native vs olm16: "
          f"max |dy| = {np.abs(y_olm - y_native).max():.2e} "
          f"(rel {np.abs(y_olm - y_native).max() / np.abs(y_native).max():.2e})")

    # 4) whole-model forward under tpmm numerics
    m_exact = Model(cfg, DotEngine(mode="native"))
    m_tp = Model(cfg, DotEngine(mode="tpmm16", use_pallas=False))
    params = m_exact.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    le, _ = m_exact.forward(params, batch)
    lt, _ = m_tp.forward(params, batch)
    le, lt = np.asarray(le), np.asarray(lt)
    agree = (le.argmax(-1) == lt.argmax(-1)).mean()
    print(f"\nmodel forward, native vs tpmm16 numerics: "
          f"max |dlogit| = {np.abs(le - lt).max():.3f}, "
          f"argmax agreement = {agree * 100:.1f}%")


if __name__ == "__main__":
    main()
