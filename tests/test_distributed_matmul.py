"""Mesh-sharded olm GEMMs + the EngineSpec/ServeReport API surface.

Two tiers of tests live here:

  * the sharded sweeps need a REAL multi-device mesh — they run under
    REPRO_TEST_DEVICES=8 (tests/conftest.py forces
    --xla_force_host_platform_device_count=8 before jax loads; the CI
    `distributed` job sets it) and skip cleanly on the default
    single-device tier-1 run. The contract they pin: partition "m"/"n"
    is BIT-IDENTICAL to single-device `olm_matmul` for every registered
    mode (full and truncated), partition "k" psums f32 partials and
    stays within `olm_error_bound` (reduction order differs — the one
    documented distributed numerics caveat).
  * the EngineSpec round-trip/shim/validation tests, the ServeEngine
    `engine=` front-door tests, the ServeReport alias tests, and the
    bench-worker subprocess smoke all run on ANY device count — they are
    part of plain tier-1.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.olm_array import MATMUL_TILING, engine_for
from repro.core.numerics import (TRUNCATED_SPECS, DotEngine, EngineSpec,
                                 resolve_engine)
from repro.kernels.online_dot.matmul import olm_error_bound, olm_matmul
from repro.kernels.online_dot.matmul_sharded import (gemm_partition_specs,
                                                     local_shapes,
                                                     olm_matmul_sharded,
                                                     sharded_traffic)
from repro.serving.report import ServeReport

# Every registered olm matmul mode: (n_bits, trunc-or-None).
FULL_WIDTHS = (8, 16, 24, 32)
ALL_CASES = [(n, None) for n in FULL_WIDTHS] + list(TRUNCATED_SPECS)
MESH_DEVICES = 8


def _label(n, p):
    return f"olm{n}" if p is None else f"olm{n}t{p}"


@pytest.fixture(scope="module")
def mesh8():
    if len(jax.devices()) < MESH_DEVICES:
        pytest.skip(f"needs {MESH_DEVICES} devices (REPRO_TEST_DEVICES="
                    f"{MESH_DEVICES}); jax sees {len(jax.devices())}")
    return jax.make_mesh((MESH_DEVICES,), ("model",))


@pytest.fixture(scope="module")
def operands():
    rng = np.random.default_rng(0xD15C)
    S = 32
    x = rng.standard_normal((S, S)).astype(np.float32)
    w = rng.standard_normal((S, S)).astype(np.float32)
    return x, w


class TestShardedSweep:
    """The sharded-vs-single-device contract, every registered mode."""

    @pytest.mark.parametrize("n,p", ALL_CASES,
                             ids=[_label(n, p) for n, p in ALL_CASES])
    @pytest.mark.parametrize("part", ["m", "n"])
    def test_output_sharded_bit_identical(self, mesh8, operands, n, p, part):
        x, w = operands
        ref = olm_matmul(x, w, n_bits=n, trunc=p)
        out = olm_matmul_sharded(x, w, mesh=mesh8, partition=part,
                                 n_bits=n, trunc=p)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    @pytest.mark.parametrize("n,p", ALL_CASES,
                             ids=[_label(n, p) for n, p in ALL_CASES])
    def test_k_sharded_within_bound(self, mesh8, operands, n, p):
        x, w = operands
        out = np.asarray(olm_matmul_sharded(x, w, mesh=mesh8, partition="k",
                                            n_bits=n, trunc=p))
        exact = x.astype(np.float64) @ w.astype(np.float64)
        bound = np.asarray(olm_error_bound(x, w, n_bits=n, trunc=p))
        assert (np.abs(out - exact) <= bound).all()

    def test_k_sharded_not_assumed_identical(self, mesh8, operands):
        # Documentation guard: the k path is only BOUND-accurate. If it
        # ever became bit-identical too this assert would flag it so the
        # docs/bench markers could be tightened — today the psum order
        # genuinely differs from the sequential K-tile walk.
        x, w = operands
        ref = np.asarray(olm_matmul(x, w, n_bits=16))
        out = np.asarray(olm_matmul_sharded(x, w, mesh=mesh8, partition="k",
                                            n_bits=16))
        assert not np.array_equal(out, ref)

    def test_auto_tiling_bit_identical(self, mesh8, operands):
        # tiling="auto" tunes on the LOCAL shard shape; block shapes are
        # bit-invariant and k_tile stays pinned, so auto == static on
        # the output-sharded paths.
        x, w = operands
        for part in ("m", "n"):
            a = olm_matmul_sharded(x, w, mesh=mesh8, partition=part,
                                   n_bits=16, tiling="auto")
            b = olm_matmul_sharded(x, w, mesh=mesh8, partition=part,
                                   n_bits=16)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_divisibility_error(self, mesh8):
        x = np.ones((12, 16), np.float32)   # 12 % 8 != 0
        w = np.ones((16, 16), np.float32)
        with pytest.raises(ValueError, match="divisible by the mesh axis"):
            olm_matmul_sharded(x, w, mesh=mesh8, partition="m", n_bits=16)

    def test_unknown_axis_error(self, mesh8, operands):
        x, w = operands
        with pytest.raises(ValueError, match="mesh has no axis"):
            olm_matmul_sharded(x, w, mesh=mesh8, partition="m",
                               axis="nope", n_bits=16)


class TestEngineDispatch:
    """DotEngine(mesh=, shard=) routes _olm_dot through the sharded
    front-end — same numerics contract as calling it directly."""

    @pytest.mark.parametrize("part", ["m", "n"])
    def test_engine_sharded_matches_single_device(self, mesh8, operands,
                                                  part):
        x, w = operands
        single = DotEngine(mode="olm16")
        sharded = DotEngine(mode="olm16", mesh=mesh8, shard=part)
        np.testing.assert_array_equal(np.asarray(sharded.dot(x, w)),
                                      np.asarray(single.dot(x, w)))

    def test_engine_k_sharded_within_bound(self, mesh8, operands):
        x, w = operands
        eng = DotEngine(mode="olm32t16", mesh=mesh8, shard="k")
        out = np.asarray(eng.dot(x, w))
        exact = x.astype(np.float64) @ w.astype(np.float64)
        bound = np.asarray(olm_error_bound(x, w, n_bits=32, trunc=16))
        assert (np.abs(out - exact) <= bound).all()

    def test_engine_3d_lead_axes(self, mesh8):
        # _lowered_dot flattens (..., K) onto 2-D before the sharded
        # front-end sees it; the flattened M must still divide the mesh.
        rng = np.random.default_rng(7)
        x = rng.standard_normal((4, 8, 32)).astype(np.float32)
        w = rng.standard_normal((32, 32)).astype(np.float32)
        single = DotEngine(mode="olm16")
        sharded = DotEngine(mode="olm16", mesh=mesh8, shard="m")
        out = sharded.dot(x, w)
        assert out.shape == (4, 8, 32)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(single.dot(x, w)))

    def test_engine_auto_tiling_sharded(self, mesh8, operands):
        x, w = operands
        auto = DotEngine(mode="olm16", mesh=mesh8, shard="n", tiling="auto")
        static = DotEngine(mode="olm16", mesh=mesh8, shard="n")
        np.testing.assert_array_equal(np.asarray(auto.dot(x, w)),
                                      np.asarray(static.dot(x, w)))

    def test_mesh_without_shard_stays_single_device(self, mesh8, operands):
        # mesh= alone is inert: shard= is the opt-in.
        x, w = operands
        eng = DotEngine(mode="olm16", mesh=mesh8)
        np.testing.assert_array_equal(
            np.asarray(eng.dot(x, w)),
            np.asarray(DotEngine(mode="olm16").dot(x, w)))


class TestPartitionSpecs:
    def test_specs_and_local_shapes(self):
        from jax.sharding import PartitionSpec as P
        (xs, ws), out = gemm_partition_specs("m", "model")
        assert (xs, ws, out) == (P("model", None), P(None, None),
                                 P("model", None))
        (xs, ws), out = gemm_partition_specs("k", "model")
        assert (xs, ws, out) == (P(None, "model"), P("model", None),
                                 P(None, None))
        assert local_shapes(64, 32, 16, "m", 8) == (8, 32, 16)
        assert local_shapes(64, 32, 16, "n", 8) == (64, 4, 16)
        assert local_shapes(64, 32, 16, "k", 8) == (64, 32, 2)
        with pytest.raises(ValueError, match="unknown GEMM partition"):
            gemm_partition_specs("q")

    def test_sharder_reexport(self):
        from repro.distributed.sharding import \
            gemm_partition_specs as from_sharding
        assert from_sharding("n", "model") == gemm_partition_specs(
            "n", "model")

    def test_traffic_ledger(self):
        mn = sharded_traffic(64, 64, 64, partition="m", devices=8, n_bits=16)
        k = sharded_traffic(64, 64, 64, partition="k", devices=8, n_bits=16)
        assert mn["collective_bytes"] == 0
        # ring reduce-scatter + all-gather of the (M, N) f32 output
        assert k["collective_bytes"] == 8 * 64 * 64 * 7
        # per-device local traffic shrinks with the shard
        assert k["local"]["fused_bytes"] < \
            sharded_traffic(64, 64, 64, partition="k", devices=2,
                            n_bits=16)["local"]["fused_bytes"]


class TestEngineSpec:
    """The unified construction front door (no mesh needed)."""

    @pytest.mark.parametrize("eng", [
        DotEngine(),
        DotEngine(mode="olm16"),
        DotEngine(mode="olm32t16", tiling="auto"),
        DotEngine(mode="olm24", k_tile=8, block_m=16, block_n=8),
        DotEngine(mode="olm16", layer_modes={"head": "olm32"}),
        DotEngine(mode="olm16", shard="k", shard_axis="data"),
    ], ids=lambda e: e.mode + (f"+{e.shard}" if e.shard else ""))
    def test_round_trip(self, eng):
        assert resolve_engine(eng.spec()) == eng

    def test_structural_mode(self):
        assert resolve_engine(EngineSpec(n_bits=16)).mode == "olm16"
        assert resolve_engine(EngineSpec(n_bits=32, trunc=16)).mode \
            == "olm32t16"

    def test_structural_mode_unregistered(self):
        with pytest.raises(ValueError, match="unregistered mode"):
            resolve_engine(EngineSpec(n_bits=32, trunc=7))

    def test_mode_and_n_bits_mutually_exclusive(self):
        with pytest.raises(ValueError, match="not both"):
            EngineSpec(mode="olm16", n_bits=16)

    def test_trunc_requires_n_bits(self):
        with pytest.raises(ValueError, match="trunc"):
            EngineSpec(trunc=16)

    def test_base_inheritance_and_none_clears(self):
        base = DotEngine(mode="olm16", k_tile=8, tiling="auto")
        # unset fields inherit from base; mode overrides
        eng = resolve_engine(EngineSpec(mode="olm24"), base=base)
        assert (eng.mode, eng.k_tile, eng.tiling) == ("olm24", 8, "auto")
        # explicit None CLEARS an inherited pin (not the same as unset)
        eng = resolve_engine(EngineSpec(k_tile=None), base=base)
        assert eng.k_tile is None and eng.tiling == "auto"

    def test_mesh_arg_resolution(self, mesh8):
        base = DotEngine(mode="olm16")
        eng = resolve_engine(EngineSpec(shard="m"), base=base, mesh=mesh8)
        assert eng.mesh is mesh8 and eng.shard == "m"

    def test_engine_for_shim_equivalence(self):
        # the legacy helper is now a thin shim over resolve_engine —
        # both construction paths must agree exactly.
        assert engine_for(16) == resolve_engine(
            EngineSpec(mode="olm16", tiling="auto"))
        assert engine_for(32, trunc=16, tiling=None) == resolve_engine(
            EngineSpec(mode="olm32t16", **MATMUL_TILING))
        assert engine_for(16, block_n=32) == resolve_engine(
            EngineSpec(mode="olm16", tiling="auto", block_n=32))

    def test_engine_for_errors_preserved(self):
        with pytest.raises(ValueError, match="no olm matmul mode"):
            engine_for(12)
        with pytest.raises(ValueError, match="no truncated olm mode"):
            engine_for(32, trunc=7)

    def test_dot_engine_shard_validation(self):
        with pytest.raises(ValueError, match="unknown DotEngine shard"):
            DotEngine(mode="olm16", shard="q")

    def test_spec_hashable(self):
        s = EngineSpec(mode="olm16", layer_modes={"mlp": "olm32t16"})
        assert hash(s) == hash(EngineSpec(mode="olm16",
                                          layer_modes={"mlp": "olm32t16"}))


class TestServeEngineFrontDoor:
    """ServeEngine(engine=EngineSpec(...)) vs the legacy kwargs."""

    def _model(self):
        from repro.models.config import ModelConfig
        from repro.models.model import Model
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=16,
                          n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=512,
                          param_dtype="float32", compute_dtype="float32")
        model = Model(cfg, DotEngine())
        return model, model.init(jax.random.PRNGKey(0))

    def _run(self, model, params, **kw):
        from repro.serving.engine import Request, ServeEngine
        eng = ServeEngine(model, params, slots=2, max_len=16, **kw)
        rng = np.random.default_rng(0)
        for rid in range(3):
            eng.submit(Request(rid=rid,
                               prompt=rng.integers(1, 512, 5).astype(np.int32),
                               max_new_tokens=4))
        done = sorted(eng.run(), key=lambda r: r.rid)
        return eng, [list(r.output) for r in done]

    def test_engine_spec_equals_legacy(self):
        model, params = self._model()
        e_new, out_new = self._run(model, params,
                                   engine=EngineSpec(mode="olm16",
                                                     tiling="auto"))
        e_old, out_old = self._run(model, params, dot_mode="olm16",
                                   dot_tiling="auto")
        assert out_new == out_old
        assert e_new.model.eng == e_old.model.eng

    def test_engine_and_legacy_mutually_exclusive(self):
        from repro.serving.engine import ServeEngine
        model, params = self._model()
        with pytest.raises(ValueError, match="not both"):
            ServeEngine(model, params, engine=EngineSpec(mode="olm16"),
                        dot_mode="olm16")

    def test_spec_carries_serving_fields(self):
        model, params = self._model()
        spec = EngineSpec(mode="olm16",
                          quality_tiers={"gold": "olm32", "bronze": "olm8"},
                          degrade_ladder=("olm16", "olm8"))
        eng, _ = self._run(model, params, engine=spec)
        assert eng.quality_tiers["gold"] == "olm32"
        assert eng.quality_tiers["bronze"] == "olm8"
        assert eng.degrade is not None
        assert eng.degrade.ladder == ("olm16", "olm8")


class TestServeReport:
    def test_empty_equals_dict(self):
        assert ServeReport() == {}

    def test_renamed_counter_aliases(self):
        rep = ServeReport({"preempts": 3, "retries": 1, "degrades": 2})
        assert rep["n_preempts"] == 3
        assert rep["n_retries"] == 1
        assert rep["n_degraded"] == 2
        assert rep.get("n_preempts") == 3

    def test_reason_aliases(self):
        rep = ServeReport({"finish_reasons": {"eos": 4, "deadline": 1}})
        assert rep["n_deadline"] == 1
        assert rep["n_eos"] == 4
        assert rep["n_cache_full"] == 0      # absent reason -> old 0 default
        assert "n_deadline" in rep

    def test_typo_still_raises(self):
        rep = ServeReport({"finish_reasons": {}, "preempts": 0})
        with pytest.raises(KeyError):
            rep["n_deadlnie"]
        assert "n_deadlnie" not in rep

    def test_canonical_keys_only_in_json(self):
        rep = ServeReport({"finish_reasons": {"deadline": 1}, "preempts": 2})
        assert set(json.loads(json.dumps(rep))) == {"finish_reasons",
                                                    "preempts"}
        assert set(rep) == {"finish_reasons", "preempts"}

    def test_producers_return_servereport(self):
        from repro.serving.engine import ServeEngine
        from repro.serving.replay import (ReplayConfig, build_workload,
                                          run_replay)
        model, params = TestServeEngineFrontDoor()._model()
        engine = ServeEngine(model, params, slots=2, max_len=32)
        _, rep = run_replay(engine, build_workload(ReplayConfig(
            n_requests=3, max_new_range=(2, 2), prompt_len_range=(4, 8))))
        assert isinstance(rep, ServeReport)
        assert "finish_reasons" in rep and "preempts" in rep
        assert rep["n_preempts"] == rep["preempts"]
        assert isinstance(engine.latency_report([]), ServeReport)
        assert isinstance(engine.kv_report(), ServeReport)


class TestBenchWorkerSmoke:
    def test_worker_subprocess(self, tmp_path):
        """The olm_matmul_distributed bench path end to end: the worker
        forces its own 8-device host platform, so this runs (and the
        sharded contract is asserted) even on the 1-device tier-1 CI."""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.abspath("src") + os.pathsep + \
            env.get("PYTHONPATH", "")
        env.pop("XLA_FLAGS", None)    # the worker sets its own
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.distributed_worker",
             "--devices", "8", "--size", "32", "--widths", "16",
             "--trunc", "32:16"],
            capture_output=True, text=True, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        assert proc.returncode == 0, proc.stderr
        out = json.loads(proc.stdout)
        assert out["devices"] == 8
        ops = {r["op"] for r in out["rows"]}
        assert ops == {f"olm_matmul_distributed/{lab}/{part}"
                       for lab in ("olm16", "olm32t16")
                       for part in ("m", "n", "k")}
        for r in out["rows"]:
            if r["op"].endswith(("/m", "/n")):
                assert r["ulp"] == 0.0 and r["bytes_float"] == 0
            else:
                assert 0 <= r["ulp"] <= 1.0 and r["bytes_float"] > 0
