"""Pallas online_mul kernel vs jnp ref vs gold, shape/dtype sweeps."""
import numpy as np
import pytest

from repro.compat import enable_x64
from repro.core.online_mul import online_multiply
from repro.core.precision import OnlinePrecision
from repro.kernels.online_mul.ops import online_mul
from repro.kernels.online_mul.ref import online_mul_batch_ref, schedule_arrays

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


def _digits(rng, B, n):
    return (rng.integers(-1, 2, size=(B, n)).astype(np.int32),
            rng.integers(-1, 2, size=(B, n)).astype(np.int32))


@pytest.mark.parametrize("n", [8, 16, 24, 32])
@pytest.mark.parametrize("B", [64, 257])
def test_pallas_equals_ref(rng, n, B):
    xd, yd = _digits(rng, B, n)
    cfg = OnlinePrecision(n=n)
    zp, Zp = online_mul(xd, yd, cfg, use_pallas=True, block_b=64)
    with enable_x64(True):
        zr, Zr = online_mul_batch_ref(xd, yd, n=n)
        np.testing.assert_array_equal(np.asarray(zp), np.asarray(zr))
        np.testing.assert_array_equal(np.asarray(Zp), np.asarray(Zr))


@pytest.mark.parametrize("n", [8, 16, 24])
def test_pallas_full_mode(rng, n):
    xd, yd = _digits(rng, 128, n)
    cfg = OnlinePrecision(n=n, truncated=False, tail_gating=False)
    zp, Zp = online_mul(xd, yd, cfg, use_pallas=True, block_b=128)
    with enable_x64(True):
        zr, Zr = online_mul_batch_ref(
            xd, yd, n=n, truncated=False, tail_gating=False)
        np.testing.assert_array_equal(np.asarray(zp), np.asarray(zr))
        np.testing.assert_array_equal(np.asarray(Zp), np.asarray(Zr))


@pytest.mark.parametrize("n", [8, 16, 32])
def test_pallas_equals_gold(rng, n):
    xd, yd = _digits(rng, 32, n)
    cfg = OnlinePrecision(n=n)
    zp, Zp = online_mul(xd, yd, cfg, use_pallas=True, block_b=32)
    zp, Zp = np.asarray(zp), np.asarray(Zp)
    for i in range(32):
        tr = online_multiply([int(v) for v in xd[i]], [int(v) for v in yd[i]], cfg)
        assert tr.z_digits == [int(v) for v in zp[i]]
        assert tr.z_int == int(Zp[i])


def test_int32_guard():
    # full-design n=32 exceeds the int32 datapath; kernel must refuse
    cfg = OnlinePrecision(n=32, truncated=False, tail_gating=False)
    assert int(schedule_arrays(cfg).max()) + 3 > 31
    xd = np.zeros((64, 32), np.int32)
    with pytest.raises(ValueError):
        from repro.kernels.online_mul.kernel import online_mul_pallas
        online_mul_pallas(xd, xd, n=32, truncated=False,
                          tail_gating=False, block_b=64)


def test_accuracy_vs_exact_product(rng):
    n, B = 16, 4096
    xd, yd = _digits(rng, B, n)
    cfg = OnlinePrecision(n=n)
    _, Z = online_mul(xd, yd, cfg, use_pallas=True)
    w = 0.5 ** np.arange(1, n + 1)
    exact = (xd @ w) * (yd @ w)
    got = np.asarray(Z).astype(np.float64) / (1 << n)
    assert np.max(np.abs(got - exact)) * (1 << n) <= 1.1  # <= 1.1 ulp


if HAVE_HYP:

    @given(n=st.sampled_from([8, 16, 24, 32]),
           seed=st.integers(0, 2**31 - 1),
           B=st.sampled_from([16, 48]))
    @settings(max_examples=25, deadline=None)
    def test_property_pallas_gold_bitexact(n, seed, B):
        r = np.random.default_rng(seed)
        xd = r.integers(-1, 2, size=(B, n)).astype(np.int32)
        yd = r.integers(-1, 2, size=(B, n)).astype(np.int32)
        cfg = OnlinePrecision(n=n)
        zp, Zp = online_mul(xd, yd, cfg, use_pallas=True, block_b=16)
        i = int(r.integers(0, B))
        tr = online_multiply([int(v) for v in xd[i]], [int(v) for v in yd[i]], cfg)
        assert tr.z_digits == [int(v) for v in np.asarray(zp)[i]]
