"""Truncated working-precision mode family olm{n}t{p}: the error-profile
digit schedule (Fig. 7 shape at p output digits), bit-identity of the
tier to the p-digit array, max error vs the f64 oracle inside the
extended olm_error_bound over ragged + GEMV shapes, the p/n digit-byte
cut, tuning-cache tier separation, per-layer precision assignment
(DotEngine.layer_modes / for_role), the hwmodel truncated-vs-full delta,
and serving quality_tier token-level behavior.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.olm_array import (MATMUL_MODES, TRUNCATED_MODES,
                                     TRUNCATED_PRECISIONS, engine_for)
from repro.core.hwmodel import truncated_delta
from repro.core.numerics import TRUNCATED_SPECS, DotEngine
from repro.core.online_mul import working_precision
from repro.core.precision import (OnlinePrecision, reduced_precision,
                                  truncation_schedule)
from repro.kernels.online_dot.matmul import (digit_traffic, olm_error_bound,
                                             olm_matmul)
from repro.kernels.online_dot.tuning import (Tiling, TuningCache, bucket_key,
                                             get_tiling, pinned_k_tile)
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.serving.engine import Request, ServeEngine

# (M, K) @ (K, N): a ragged GEMM (nothing divides the default tiles)
# and a decode-shaped GEMV.
SHAPES = (((5, 37), (37, 9)), ((1, 64), (64, 7)))


def _operands(shape, seed=0):
    rng = np.random.default_rng(seed)
    (M, K), (_, N) = shape
    return (rng.standard_normal((M, K)).astype(np.float32),
            rng.standard_normal((K, N)).astype(np.float32))


class TestSchedule:
    def test_validation(self):
        with pytest.raises(ValueError, match="truncated working precision"):
            truncation_schedule(16, 16)      # p >= n: not a truncation
        with pytest.raises(ValueError, match="truncated working precision"):
            truncation_schedule(16, 20)
        with pytest.raises(ValueError, match="truncated working precision"):
            truncation_schedule(16, 3)       # below the delta+1 floor

    @pytest.mark.parametrize("n,p", sorted(TRUNCATED_SPECS))
    def test_is_the_p_digit_array(self, n, p):
        cfg = truncation_schedule(n, p)
        assert cfg == OnlinePrecision(n=p)

    @pytest.mark.parametrize("n,p", sorted(TRUNCATED_SPECS))
    def test_fig7_up_then_down_shape(self, n, p):
        """The per-slice live width T(j) ramps up to the Eq. 8 plateau
        and back down along the error profile — never exceeding the
        working precision, and strictly below the full n-digit
        schedule's total activity."""
        cfg = truncation_schedule(n, p)
        T = [working_precision(cfg, j) for j in range(-cfg.delta, cfg.n)]
        peak = max(T)
        assert peak <= reduced_precision(p)
        rise = T.index(peak)
        assert all(a <= b for a, b in zip(T[:rise], T[1:rise + 1]))
        assert all(a >= b for a, b in zip(T[rise:], T[rise + 1:]))
        assert T[-1] < peak                  # the decreasing tail exists
        full = OnlinePrecision(n=n)
        T_full = [working_precision(full, j)
                  for j in range(-full.delta, full.n)]
        assert sum(T) < sum(T_full)


class TestRegistration:
    def test_specs_registered_and_servable(self):
        modes = DotEngine.modes()
        for (n, p), name in sorted(TRUNCATED_MODES.items()):
            assert name == f"olm{n}t{p}"
            assert name in modes
            assert engine_for(n, trunc=p).mode == name
        # acceptance: at least one 16- and one 32-wide tier exists
        assert any(n == 16 for n, _ in TRUNCATED_SPECS)
        assert any(n == 32 for n, _ in TRUNCATED_SPECS)

    def test_precisions_table(self):
        for (n, p), cfg in TRUNCATED_PRECISIONS.items():
            assert cfg.n == p

    def test_engine_for_rejects_unknown_pairs(self):
        with pytest.raises(ValueError, match="no truncated olm mode"):
            engine_for(16, trunc=11)
        with pytest.raises(ValueError, match="no truncated olm mode"):
            engine_for(8, trunc=6)


class TestNumerics:
    @pytest.mark.parametrize("n,p", sorted(TRUNCATED_SPECS))
    @pytest.mark.parametrize("shape", SHAPES)
    def test_error_within_extended_bound(self, n, p, shape):
        a, b = _operands(shape)
        y = np.asarray(olm_matmul(jnp.asarray(a), jnp.asarray(b),
                                  n_bits=n, trunc=p))
        oracle = a.astype(np.float64) @ b.astype(np.float64)
        bound = np.asarray(olm_error_bound(jnp.asarray(a), jnp.asarray(b),
                                           n_bits=n, trunc=p))
        assert np.all(np.abs(y - oracle) <= bound)

    @pytest.mark.parametrize("n,p", sorted(TRUNCATED_SPECS))
    def test_bit_identical_to_p_digit_mode(self, n, p):
        a, b = _operands(SHAPES[0], seed=3)
        tier = np.asarray(olm_matmul(jnp.asarray(a), jnp.asarray(b),
                                     n_bits=n, trunc=p))
        plain = np.asarray(olm_matmul(jnp.asarray(a), jnp.asarray(b),
                                      n_bits=p))
        np.testing.assert_array_equal(tier, plain)

    def test_trunc_none_bound_unchanged(self):
        a, b = _operands(SHAPES[0], seed=4)
        base = np.asarray(olm_error_bound(jnp.asarray(a), jnp.asarray(b),
                                          n_bits=16))
        ext = np.asarray(olm_error_bound(jnp.asarray(a), jnp.asarray(b),
                                         n_bits=16, trunc=12))
        assert np.all(ext > base)            # truncation term is additive

    @pytest.mark.parametrize("n,p", sorted(TRUNCATED_SPECS))
    def test_digit_byte_cut_is_exactly_p_over_n(self, n, p):
        full = digit_traffic(64, 64, 32, n_bits=n)
        cut = digit_traffic(64, 64, 32, n_bits=n, trunc=p)
        assert cut["grid_bytes"] * n == full["grid_bytes"] * p
        # the fused path moves raw float tiles: width-independent
        assert cut["fused_bytes"] == full["fused_bytes"]

    def test_digit_traffic_validates_trunc(self):
        with pytest.raises(ValueError):
            digit_traffic(8, 8, 8, n_bits=16, trunc=16)

    @pytest.mark.parametrize("mode", sorted(TRUNCATED_MODES.values()))
    def test_mode_runs_through_dot_engine(self, mode):
        a, b = _operands(SHAPES[1], seed=5)
        eng = DotEngine(mode=mode)
        y = np.asarray(eng.dot(jnp.asarray(a), jnp.asarray(b)))
        assert y.shape == (a.shape[0], b.shape[1])
        assert np.isfinite(y).all()


class TestTuningSeparation:
    def test_bucket_keys_differ_per_tier(self):
        keys = {bucket_key(64, 64, 32, 16)}
        for n, p in TRUNCATED_SPECS:
            k = bucket_key(64, 64, 32, n, p)
            assert k.endswith(f"b{n}t{p}")
            assert k not in keys
            keys.add(k)

    def test_cache_entries_do_not_cross_tiers(self, tmp_path):
        cache = TuningCache(str(tmp_path / "tuning.json"))
        cache.store(64, 64, 32, 32, Tiling(16, 4, 4), source="measured")
        assert cache.lookup(64, 64, 32, 32) is not None
        assert cache.lookup(64, 64, 32, 32, trunc=20) is None
        cache.store(64, 64, 32, 32, Tiling(16, 2, 8), source="measured",
                    trunc=20)
        assert cache.lookup(64, 64, 32, 32, trunc=20) == Tiling(16, 2, 8)
        assert cache.lookup(64, 64, 32, 32) == Tiling(16, 4, 4)

    def test_get_tiling_buckets_and_tags_per_tier(self, tmp_path):
        cache = TuningCache(str(tmp_path / "tuning.json"))
        t = get_tiling(64, 64, 512, 32, cache, trunc=16)
        assert t["k_tile"] == pinned_k_tile(512, 16)
        # the heuristic entry it wrote is keyed t{p} and tagged trunc
        key = bucket_key(64, 64, 512, 32, 16)
        entry = cache._load()[key]
        assert entry["trunc"] == 16
        assert bucket_key(64, 64, 512, 32) not in cache._load()


class TestLayerModes:
    def test_roles_resolve(self):
        eng = DotEngine(mode="olm32",
                        layer_modes={"mlp": "olm32t20", "head": "olm32"})
        assert eng.for_role("mlp").mode == "olm32t20"
        assert eng.for_role("mlp").layer_modes is None
        assert eng.for_role("attn") is eng
        assert eng.for_role("head") is eng   # same-mode override: no-op
        assert hash(eng) is not None         # normalized tuple stays static

    def test_validation(self):
        with pytest.raises(ValueError, match="unknown layer_modes roles"):
            DotEngine(mode="olm16", layer_modes={"lm_head": "olm16"})
        with pytest.raises(ValueError, match="unregistered modes"):
            DotEngine(mode="olm16", layer_modes={"mlp": "olm16t11"})
        with pytest.raises(ValueError, match="unknown GEMM role"):
            DotEngine(mode="olm16").for_role("embedding")

    def test_model_forward_uses_per_role_engines(self):
        """A model whose MLPs run a truncated tier must reproduce the
        forward of the same model hand-assembled at those modes — and
        differ from the all-base forward."""
        cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=16,
                          n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                          param_dtype="float32", compute_dtype="float32")
        base = Model(cfg, DotEngine(mode="olm16"))
        params = base.init(jax.random.PRNGKey(0))
        split = Model(cfg, DotEngine(mode="olm16",
                                     layer_modes={"mlp": "olm16t10"}))
        batch = {"tokens": np.arange(6, dtype=np.int32)[None] % 64}
        y_base, _ = base.forward(params, batch)
        y_split, _ = split.forward(params, batch)
        assert not np.array_equal(np.asarray(y_base), np.asarray(y_split))
        # all-roles override == plain engine at the override mode
        all_t = Model(cfg, DotEngine(
            mode="olm16", layer_modes={"attn": "olm16t10",
                                       "mlp": "olm16t10",
                                       "head": "olm16t10"}))
        plain = Model(cfg, DotEngine(mode="olm16t10"))
        np.testing.assert_array_equal(
            np.asarray(all_t.forward(params, batch)[0]),
            np.asarray(plain.forward(params, batch)[0]))


class TestHwModel:
    @pytest.mark.parametrize("n,p", sorted(TRUNCATED_SPECS))
    def test_delta_reports_positive_savings(self, n, p):
        d = truncated_delta(n, p)
        for key in ("area", "power", "activity"):
            assert 0 < d[f"{key}_save_pct"] < 100
            assert d[f"trunc_{key}"] < d[f"full_{key}"]
        assert d["latency_delta"] == n - p
        assert d["full_latency"] == n + 4    # n + delta + 1
        assert d["trunc_latency"] == p + 4

    def test_savings_land_in_paper_band(self):
        """Table I reports 38%/44% power/area savings for Eq. 8
        truncation; the deeper olm{n}t{p} tiers must save at least as
        much as a shallow one, monotonically in the cut depth."""
        ps = sorted((p for n, p in TRUNCATED_SPECS if n == 32),
                    reverse=True)
        saves = [truncated_delta(32, p)["area_save_pct"] for p in ps]
        assert saves == sorted(saves)


VOCAB = 64


def _serve_model(mode="olm16", **eng_over):
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=VOCAB,
                      param_dtype="float32", compute_dtype="float32")
    model = Model(cfg, DotEngine(mode=mode, **eng_over))
    return model, model.init(jax.random.PRNGKey(1))


def _prompts(lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, VOCAB, n).astype(np.int32) for n in lens]


class TestServingQualityTier:
    def test_unknown_tier_rejected(self):
        model, params = _serve_model()
        eng = ServeEngine(model, params, slots=1, max_len=16,
                          quality_tiers={"fast": "olm16t10"})
        with pytest.raises(ValueError, match="unknown quality_tier"):
            eng.submit(Request(rid=0, prompt=_prompts([4])[0],
                               quality_tier="turbo"))

    def test_tier_mode_must_be_registered(self):
        model, params = _serve_model()
        with pytest.raises(ValueError, match="unknown DotEngine mode"):
            ServeEngine(model, params, slots=1, max_len=16,
                        quality_tiers={"fast": "olm16t11"})

    def test_tier_tokens_match_dedicated_deployment(self):
        """Token-level acceptance: a request decoded under
        quality_tier="fast" must emit exactly the tokens a dedicated
        olm16t10 deployment emits, and the default tier must be
        unaffected by the tiers mapping existing."""
        model, params = _serve_model()
        prompts = _prompts([5, 7])

        def serve(tier, **kw):
            eng = ServeEngine(model, params, slots=2, max_len=16, **kw)
            for rid, p in enumerate(prompts):
                eng.submit(Request(rid=rid, prompt=p, max_new_tokens=4,
                                   quality_tier=tier))
            return sorted(eng.run(), key=lambda r: r.rid)

        tiered = serve("fast", quality_tiers={"fast": "olm16t10"})
        dedicated_eng = ServeEngine(model, params, slots=2, max_len=16,
                                    dot_mode="olm16t10")
        for rid, p in enumerate(prompts):
            dedicated_eng.submit(Request(rid=rid, prompt=p,
                                         max_new_tokens=4))
        dedicated = sorted(dedicated_eng.run(), key=lambda r: r.rid)
        for a, b in zip(tiered, dedicated):
            assert a.output == b.output
        base_with = serve(None, quality_tiers={"fast": "olm16t10"})
        base_without = serve(None)
        for a, b in zip(base_with, base_without):
            assert a.output == b.output
        # the tier actually changes numerics for this checkpoint
        assert [r.output for r in tiered] != [r.output for r in base_with]

    def test_mixed_queue_stays_tier_homogeneous_and_fifo(self):
        """Interleaved base/fast submissions: every request completes,
        each under its own tier's numerics, with strict FIFO across the
        tier boundary (a later same-tier request never jumps a
        different-tier head)."""
        model, params = _serve_model()
        prompts = _prompts([4, 5, 6, 4], seed=2)
        tiers = [None, "fast", "fast", None]
        eng = ServeEngine(model, params, slots=2, max_len=16,
                          quality_tiers={"fast": "olm16t10"})
        for rid, (p, tier) in enumerate(zip(prompts, tiers)):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=3,
                               quality_tier=tier))
        done = sorted(eng.run(), key=lambda r: r.rid)
        assert len(done) == 4
        assert all(r.finish_reason == "length" for r in done)
        # per-request reference: a dedicated engine at that tier's mode
        for req, tier in zip(done, tiers):
            mode = "olm16t10" if tier == "fast" else "olm16"
            ref_eng = ServeEngine(model, params, slots=1, max_len=16,
                                  dot_mode=mode)
            ref_eng.submit(Request(rid=0, prompt=prompts[req.rid],
                                   max_new_tokens=3))
            ref = ref_eng.run()[0]
            assert req.output == ref.output, (req.rid, tier)
        # FIFO: first-token order follows submission order
        firsts = [r.s_first for r in done]
        assert firsts == sorted(firsts)

    def test_redundant_tier_shares_compiles(self):
        model, params = _serve_model()
        eng = ServeEngine(model, params, slots=1, max_len=16,
                          quality_tiers={"same": "olm16"})
        assert eng._tier_fns["same"] is eng._tier_fns[None]
