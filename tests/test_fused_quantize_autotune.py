"""Quantize-in-kernel olm matmul + the shape-aware tiling autotuner.

Contracts under test:
  * in-kernel sd_quantize — run *inside* a Pallas kernel body — emits
    bit-identical digits and pow2 scales to the host quantizer (they
    are one shared function), across n in {8, 16};
  * the fused matmul path (raw float tiles over HBM, quantize in the
    kernel prologue) is bit-identical to the host-quantize grid path
    and the jnp broadcast oracle for every olm mode, ragged M/N/K, and
    GEMV shapes;
  * digit_traffic's fused columns: the fused path moves exactly
    grid / n_bits operand elements (>= 4x fewer bytes at every
    supported width — the acceptance gate);
  * the autotuner: cache miss -> heuristic memoized -> hit; measured
    entries persist across TuningCache instances; every produced
    tiling respects the float32-exact decode window; and
    tiling="auto" never changes numerics — only wall clock.
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.configs.olm_array import MATMUL_MODES, MATMUL_TILING, engine_for
from repro.core.numerics import DotEngine
from repro.kernels.common import sd_quantize, sd_quantize_inkernel
from repro.kernels.online_dot import tuning
from repro.kernels.online_dot.matmul import digit_traffic, olm_matmul
from repro.kernels.online_dot.ref import tree_levels
from repro.kernels.online_dot.tuning import (Tiling, TuningCache, bucket_key,
                                             get_tiling, heuristic_tiling,
                                             max_k_tile, tune)


def _pair(rng, M, K, N):
    return (jnp.asarray(rng.standard_normal((M, K)).astype(np.float32)),
            jnp.asarray(rng.standard_normal((K, N)).astype(np.float32)))


class TestInKernelQuantize:
    @pytest.mark.parametrize("n", [8, 16, 24, 32])
    def test_inside_pallas_bitwise_matches_host(self, rng, n):
        """The quantizer run as a Pallas kernel body must reproduce the
        host sd_quantize digits and scales bit for bit."""
        a = jnp.asarray(rng.standard_normal((6, 16)).astype(np.float32))

        def kern(x_ref, d_ref, s_ref):
            d, s = sd_quantize_inkernel(x_ref[...], n=n)
            d_ref[...] = d
            s_ref[...] = s

        d_k, s_k = pl.pallas_call(
            kern,
            out_shape=(jax.ShapeDtypeStruct((6, 16, n), jnp.int32),
                       jax.ShapeDtypeStruct((6, 1), jnp.float32)),
            interpret=True)(a)
        d_h, s_h = sd_quantize(a, n=n, axis=-1)
        np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_h))
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_h))
        assert set(np.unique(np.asarray(d_k))) <= {-1, 0, 1}

    def test_host_wrapper_moves_axis(self, rng):
        a = jnp.asarray(rng.standard_normal((4, 6)).astype(np.float32))
        d0, s0 = sd_quantize(a, n=8, axis=0)
        dT, sT = sd_quantize(a.T, n=8, axis=-1)
        np.testing.assert_array_equal(np.asarray(d0),
                                      np.moveaxis(np.asarray(dT), 0, 1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(sT).T)

    @pytest.mark.parametrize("mode", sorted(MATMUL_MODES.values()))
    @pytest.mark.parametrize("shape", [(5, 20, 3),   # all dims ragged
                                       (3, 7, 2),    # K < k_tile
                                       (1, 24, 5),   # GEMV, M=1
                                       (17, 40, 9)])  # multi ragged tiles
    def test_fused_bitwise_vs_host_and_oracle(self, rng, mode, shape):
        M, K, N = shape
        n_bits = int(mode.removeprefix("olm"))   # olm8..olm32
        x, w = _pair(rng, M, K, N)
        fused = np.asarray(olm_matmul(x, w, n_bits=n_bits, use_pallas=True,
                                      quantize="kernel"))
        host = np.asarray(olm_matmul(x, w, n_bits=n_bits, use_pallas=True,
                                     quantize="host"))
        oracle = np.asarray(olm_matmul(x, w, n_bits=n_bits,
                                       use_pallas=False))
        np.testing.assert_array_equal(fused, host)
        np.testing.assert_array_equal(fused, oracle)

    def test_fused_is_the_pallas_default(self, rng):
        x, w = _pair(rng, 4, 16, 4)
        got = np.asarray(olm_matmul(x, w, use_pallas=True))
        want = np.asarray(olm_matmul(x, w, use_pallas=True,
                                     quantize="kernel"))
        np.testing.assert_array_equal(got, want)

    def test_quantize_arg_validated(self):
        x = jnp.zeros((2, 8), jnp.float32)
        w = jnp.zeros((8, 2), jnp.float32)
        with pytest.raises(ValueError, match="quantize"):
            olm_matmul(x, w, quantize="device")

    def test_out_of_domain_magnitudes_fail_loud(self):
        # |a| > 2^126 has no finite pow2 scale >= 2*max|a|: the scale
        # must go inf (NaN downstream) — the legacy exp2 behavior —
        # never a silently saturated finite wrong answer
        from repro.kernels.common import pow2_scale
        a = jnp.asarray([[3e38, 1.0], [1.0, 2.0]], jnp.float32)
        s = np.asarray(pow2_scale(a, 1))
        assert np.isinf(s[0, 0])
        assert np.isfinite(s[1, 0])
        d, s2 = sd_quantize(a, n=16, axis=1)
        assert not np.asarray(d)[0].any()       # inf scale -> zero digits
        # in-domain magnitudes keep the exact >= 2*max invariant
        big = jnp.asarray([[2.0 ** 126]], jnp.float32)
        assert float(pow2_scale(big, 1)[0, 0]) == 2.0 ** 127


class TestFusedTraffic:
    @pytest.mark.parametrize("n_bits", [8, 16])
    def test_fused_is_grid_over_n(self, n_bits):
        t = digit_traffic(64, 64, 64, n_bits=n_bits)
        assert t["fused_elems"] * n_bits == t["grid_elems"]
        assert t["fused_bytes"] * n_bits == t["grid_bytes"]
        assert t["fused_vs_grid"] == n_bits
        assert t["fused_reuse"] == n_bits * t["reuse"]

    def test_acceptance_floor_4x_at_defaults(self):
        # n=16 defaults: in-kernel quantize moves 16x fewer operand
        # bytes than the host-quantize grid path — >= the 4x gate
        t = digit_traffic(64, 32, 64, n_bits=16)
        assert t["fused_bytes"] * 4 <= t["grid_bytes"]
        assert t["grid_bytes"] / t["fused_bytes"] == 16

    def test_fused_reuse_pattern_matches_grid(self):
        # same BlockSpec reuse structure: fused traffic scales with
        # M + N when one tile covers the output, like the grid path
        t1 = digit_traffic(32, 32, 16, block_m=32, block_n=32)
        t2 = digit_traffic(64, 64, 16, block_m=64, block_n=64)
        assert t1["fused_elems"] == (32 + 32) * 16
        assert t2["fused_elems"] == 2 * t1["fused_elems"]


class TestAutotunerCache:
    def test_miss_memoizes_then_hits(self, tmp_path):
        cache = TuningCache(str(tmp_path / "t.json"))
        t0 = get_tiling(64, 64, 256, 16, cache)
        assert (cache.misses, cache.hits) == (1, 0)
        t1 = get_tiling(64, 64, 256, 16, cache)
        assert (cache.misses, cache.hits) == (1, 1)
        assert t0 == t1 == heuristic_tiling(64, 64, 256, 16).as_dict()
        # same bucket (pow2 rounding) hits; different bucket misses
        get_tiling(63, 64, 255, 16, cache)
        assert (cache.misses, cache.hits) == (1, 2)
        get_tiling(1, 64, 256, 16, cache)
        assert (cache.misses, cache.hits) == (2, 2)

    def test_memoization_stays_off_disk(self, tmp_path):
        path = tmp_path / "t.json"
        get_tiling(8, 8, 16, 16, TuningCache(str(path)))
        assert not path.exists()

    def test_measured_entry_persists(self, tmp_path):
        path = str(tmp_path / "t.json")
        cache = TuningCache(path)
        best = tune(8, 8, 16, 16, cache, cap=8, repeat=1)
        assert os.path.exists(path)
        entry = json.load(open(path))["entries"][bucket_key(8, 8, 16, 16)]
        assert entry["source"] == "measured"
        assert Tiling(entry["k_tile"], entry["block_m"],
                      entry["block_n"]) == best
        # a fresh cache instance reads it back as a hit
        fresh = TuningCache(path)
        assert fresh.lookup(8, 8, 16, 16) == best
        assert (fresh.hits, fresh.misses) == (1, 0)

    def test_env_var_points_default_cache(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "env.json"))
        monkeypatch.setattr(tuning, "_DEFAULT_CACHE", None)
        assert tuning.default_cache().path == str(tmp_path / "env.json")

    def test_stale_cache_k_tile_repinned_on_read(self, tmp_path):
        # the never-changes-numerics guarantee must survive a cache
        # written by another version or a hand edit: k_tile is
        # re-pinned on every read, blocks (pure perf) are honored
        path = tmp_path / "t.json"
        entry = {"k_tile": 4, "block_m": 2, "block_n": 2,
                 "source": "measured", "shape": [8, 8, 32], "n_bits": 16}
        path.write_text(json.dumps(
            {"entries": {bucket_key(8, 8, 32, 16): entry}}))
        d = get_tiling(8, 8, 32, 16, TuningCache(str(path)))
        assert d["k_tile"] == 16                       # re-pinned
        assert (d["block_m"], d["block_n"]) == (2, 2)  # honored

    def test_tune_candidates_come_from_real_shape(self):
        # candidates must be derived from the real GEMM dims, not the
        # measurement proxy — else a capped proxy clips block_n and a
        # "measured" entry loses to the heuristic it should improve on
        cands = tuning._candidates(1, 4096, 4096, 16)
        assert heuristic_tiling(1, 4096, 4096, 16) in cands
        assert max(c.block_n for c in cands) >= 128


class TestAutotunerChoices:
    @pytest.mark.parametrize("n_bits", [8, 16, 24, 32])
    @pytest.mark.parametrize("shape", [(1, 4096, 4096), (8192, 4096, 1024),
                                       (4, 11, 3), (128, 128, 128)])
    def test_heuristic_is_always_legal(self, n_bits, shape):
        M, N, K = shape
        t = heuristic_tiling(M, N, K, n_bits)
        # per-dtype decode window: the kernel would refuse anything wider
        assert n_bits + 2 * tree_levels(t.k_tile) <= \
            tuning.decode_window(n_bits)
        # VMEM lane budget — width-aware: wide modes get fewer lanes
        assert t.block_m * t.block_n * t.k_tile <= tuning.lane_budget(n_bits)
        assert t.block_m >= 1 and t.block_n >= 1 and t.k_tile >= 1

    def test_max_k_tile_decode_window(self):
        # n <= 16: plain-f32 24-digit window (by policy — auto tilings
        # must stay bit-identical to the f32-narrow static default)
        assert max_k_tile(16) == 16
        assert max_k_tile(8) == 256
        # n = 24/32 have no f32-narrow tiling: the 48-digit wide window
        # applies (n + 2*ceil(log2 kt) <= 48)
        assert max_k_tile(24) == 4096
        assert max_k_tile(32) == 256
        assert tuning.decode_window(16) == 24
        assert tuning.decode_window(24) == 48

    def test_gemv_spends_budget_on_columns(self):
        # M=1 decode GEMV: the static 8x8 default wastes 7/8 of its
        # block_m; the heuristic must not
        t = heuristic_tiling(1, 4096, 4096, 16)
        assert t.block_m == 1
        assert t.block_n > MATMUL_TILING["block_n"]

    def test_square_gemm_beats_static_reuse(self):
        # big square GEMM: per-tile harmonic reuse must be >= static 8x8
        t = heuristic_tiling(8192, 8192, 4096, 16)
        assert 2 / (1 / t.block_m + 1 / t.block_n) >= 8


class TestAutoTilingThreading:
    def test_auto_never_changes_numerics(self, rng):
        """tiling="auto" is a pure perf choice: block shapes are
        bit-invariant and the tuner pins k_tile (the one knob that IS a
        numerics parameter) to the kernel default, so auto output is
        bit-identical to the legacy static MATMUL_TILING default and
        to the oracle — for every olm mode."""
        for M, K, N in ((9, 37, 11), (4, 48, 6)):   # incl. K where a
            x, w = _pair(rng, M, K, N)              # free tuner would
            for mode in sorted(MATMUL_MODES.values()):   # widen k_tile
                auto = np.asarray(
                    DotEngine(mode=mode, tiling="auto",
                              use_pallas=True).dot(x, w))
                static = np.asarray(
                    DotEngine(mode=mode, use_pallas=True,
                              **MATMUL_TILING).dot(x, w))
                oracle = np.asarray(
                    DotEngine(mode=mode, use_pallas=False).dot(x, w))
                np.testing.assert_array_equal(auto, static)
                np.testing.assert_array_equal(auto, oracle)

    def test_auto_pins_k_tile_to_numerics_default(self):
        from repro.kernels.online_dot.matmul import DEFAULT_K_TILE
        for (M, N, K) in ((1, 4096, 4096), (8192, 4096, 1024), (4, 6, 48)):
            for nb in (8, 16, 24, 32):
                t = heuristic_tiling(M, N, K, nb)
                # same effective slice width as the kernel's own
                # kt = min(DEFAULT_K_TILE, K) clamp
                assert min(t.k_tile, K) == min(DEFAULT_K_TILE, K)

    def test_explicit_knobs_win_over_auto(self, rng):
        # pinned k_tile must survive tiling="auto" (engine knobs win)
        eng = DotEngine(mode="olm16", tiling="auto", k_tile=4,
                        use_pallas=True)
        x, w = _pair(rng, 3, 8, 3)
        got = np.asarray(eng.dot(x, w))
        want = np.asarray(olm_matmul(x, w, k_tile=4, use_pallas=False))
        np.testing.assert_array_equal(got, want)

    def test_engine_for_defaults_to_auto(self):
        eng = engine_for(16)
        assert eng.tiling == "auto"
        assert eng.k_tile is None and eng.block_m is None
        static = engine_for(16, tiling=None)
        assert static.tiling is None
        assert (static.k_tile, static.block_m, static.block_n) == (
            MATMUL_TILING["k_tile"], MATMUL_TILING["block_m"],
            MATMUL_TILING["block_n"])
        with pytest.raises(ValueError, match="tiling"):
            engine_for(16, tiling="bogus")

    def test_unknown_tiling_rejected(self):
        with pytest.raises(ValueError, match="tiling"):
            DotEngine(mode="olm16", tiling="measured")

    def test_serve_engine_auto(self):
        from repro.models.config import ModelConfig
        from repro.models.model import Model
        from repro.serving.engine import ServeEngine
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=16,
                          n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=512,
                          param_dtype="float32", compute_dtype="float32")
        model = Model(cfg, DotEngine(mode="native"))
        eng = ServeEngine(model, params=None, slots=1, max_len=8,
                          dot_mode="olm16", dot_tiling="auto")
        assert eng.model.eng.mode == "olm16"
        assert eng.model.eng.tiling == "auto"
        eng2 = ServeEngine(model, params=None, slots=1, max_len=8,
                           dot_mode="olm16",
                           dot_tiling={"tiling": "auto", "block_n": 32})
        assert eng2.model.eng.tiling == "auto"
        assert eng2.model.eng.block_n == 32

    def test_serve_auto_clears_pinned_blocks_keeps_k_tile(self):
        # a model built with the static legacy tiling must not turn
        # dot_tiling="auto" into a silent no-op: auto clears pre-pinned
        # *block* knobs (pure perf) so the autotuner engages, but a
        # pinned k_tile is a numerics choice and must survive; knobs in
        # the same dot_tiling dict survive too
        from repro.models.config import ModelConfig
        from repro.models.model import Model
        from repro.serving.engine import ServeEngine
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=16,
                          n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=512,
                          param_dtype="float32", compute_dtype="float32")
        model = Model(cfg, engine_for(16, tiling=None))   # pinned 8x8x16
        assert model.eng.k_tile == MATMUL_TILING["k_tile"]
        eng = ServeEngine(model, params=None, slots=1, max_len=8,
                          dot_tiling="auto")
        assert eng.model.eng.tiling == "auto"
        assert eng.model.eng.k_tile == MATMUL_TILING["k_tile"]  # numerics
        assert eng.model.eng.block_m is None
        assert eng.model.eng.block_n is None
        eng2 = ServeEngine(model, params=None, slots=1, max_len=8,
                           dot_tiling={"tiling": "auto", "block_n": 64})
        assert eng2.model.eng.block_n == 64
        assert eng2.model.eng.block_m is None
        with pytest.raises(ValueError, match="only string form is 'auto'"):
            ServeEngine(model, params=None, slots=1, max_len=8,
                        dot_tiling="autotune")
