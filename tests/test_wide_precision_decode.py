"""Wide-precision (n = 24/32) decode/quantize subsystem.

Contracts under test:
  * decode policy — streams <= 24 digits stay on the plain-f32 exact
    path (n = 8/16 at default tiling: bit-for-bit the historical
    behavior), 25..48 digits take the wide decode, wider refuses;
  * wide decode exactness & x64 invariance — the int64-accumulator
    branch (under repro.compat.enable_x64), the two-limb jnp branch,
    and the in-kernel two-limb form all round the exact dyadic stream
    value to float32 once, to the identical bit pattern, and agree
    with an arbitrary-precision host reference;
  * the n = 32 quantizer — two-limb digit extraction is exact against
    a python-int reference including the closed endpoint |v| = 2^31
    that overflows the int32 path;
  * three-path bit-identity at n = 24/32 — fused kernel, host-quantize
    kernel and broadcast oracle agree bitwise over ragged + GEMV
    shapes, with and without x64;
  * olm_error_bound holds per registered mode against the f64 matmul.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import enable_x64
from repro.configs.olm_array import MATMUL_MODES
from repro.kernels.common import (DECODE_WINDOW_F32, DECODE_WINDOW_WIDE,
                                  decode_policy, decode_stream_inkernel,
                                  decode_stream_wide_inkernel,
                                  decode_stream_wide_jnp, int64_enabled,
                                  sd_quantize, sd_quantize_inkernel)
from repro.kernels.online_dot.matmul import (olm_error_bound, olm_matmul,
                                             olm_matmul_ref)


def _exact_stream_value(digits) -> np.ndarray:
    """Arbitrary-precision decode: sum_i d_i 2^-(i+1) via python ints,
    rounded to f32 only at the very end (numpy RN-even cast from the
    f64-exact dyadic value — exact up to 52-digit streams)."""
    d = np.asarray(digits, np.int64)
    m = d.shape[-1]
    scaled = d @ (np.int64(1) << np.arange(m - 1, -1, -1, dtype=np.int64))
    return (scaled.astype(np.float64) * 2.0 ** -m).astype(np.float32)


class TestDecodePolicy:
    def test_windows(self):
        assert decode_policy(1) == "f32"
        assert decode_policy(DECODE_WINDOW_F32) == "f32"
        assert decode_policy(DECODE_WINDOW_F32 + 1) == "wide"
        assert decode_policy(DECODE_WINDOW_WIDE) == "wide"
        with pytest.raises(ValueError, match="decode window"):
            decode_policy(DECODE_WINDOW_WIDE + 1)

    def test_default_tiling_streams(self):
        # at the default k_tile=16 tree (L=4): n = 8/16 stay narrow,
        # n = 24/32 go wide — the mode boundary the registry documents
        from repro.kernels.online_dot.matmul import _decode_plan
        assert _decode_plan(8, 16) == (4, False)
        assert _decode_plan(16, 16) == (4, False)
        assert _decode_plan(24, 16) == (4, True)
        assert _decode_plan(32, 16) == (4, True)


class TestWideDecode:
    @pytest.mark.parametrize("m", [28, 40, DECODE_WINDOW_WIDE])
    def test_exact_and_branch_identical(self, rng, m):
        d = jnp.asarray(rng.integers(-1, 2, size=(256, m)).astype(np.int32))
        want = _exact_stream_value(d)
        got_ambient = np.asarray(decode_stream_wide_jnp(d))
        got_kernelform = np.asarray(decode_stream_wide_inkernel(d))
        with enable_x64():
            assert int64_enabled()
            got_int64 = np.asarray(decode_stream_wide_jnp(d))
        np.testing.assert_array_equal(got_ambient, want)
        np.testing.assert_array_equal(got_kernelform, want)
        # the x64 CI axis flips which branch `ambient` took; both must
        # produce the same bits as the forced-int64 run
        np.testing.assert_array_equal(got_ambient, got_int64)

    def test_narrow_streams_match_f32_decode(self, rng):
        # inside the f32 window the wide decode degenerates to the
        # plain exact decode bit-for-bit (lo window is empty/zero)
        d = jnp.asarray(rng.integers(-1, 2, size=(64, 20)).astype(np.int32))
        np.testing.assert_array_equal(
            np.asarray(decode_stream_wide_inkernel(d)),
            np.asarray(decode_stream_inkernel(d)))

    def test_inside_pallas_body(self, rng):
        # the in-kernel two-limb decode must survive an actual
        # pallas_call and still match the host wide decode bitwise
        m = 40
        d = jnp.asarray(rng.integers(-1, 2, size=(8, m)).astype(np.int32))

        def kern(d_ref, o_ref):
            o_ref[...] = decode_stream_wide_inkernel(d_ref[...])

        got = pl.pallas_call(
            kern, out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
            interpret=True)(d)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(decode_stream_wide_jnp(d)))

    def test_window_guard(self, rng):
        d = jnp.asarray(rng.integers(-1, 2, size=(4, 49)).astype(np.int32))
        with pytest.raises(ValueError, match="wide decode"):
            decode_stream_wide_jnp(d)


class TestQuantizerN32:
    def _reference_digits(self, a, scale, n):
        """Digit grid via python ints — no 32-bit anything."""
        out = np.zeros(a.shape + (n,), np.int32)
        for idx in np.ndindex(a.shape):
            u = float(a[idx]) / float(scale[idx[:-1] + (0,)])
            v = round(u * (1 << n))          # RN-even, like jnp.round
            s = (v > 0) - (v < 0)
            for p in range(n):
                out[idx + (p,)] = s * ((abs(v) >> (n - 1 - p)) & 1)
        return out

    @pytest.mark.parametrize("n", [24, 32])
    def test_matches_python_int_reference(self, rng, n):
        a = rng.standard_normal((6, 9)).astype(np.float32)
        d, s = sd_quantize(jnp.asarray(a), n=n, axis=-1)
        d, s = np.asarray(d), np.asarray(s)
        assert set(np.unique(d)) <= {-1, 0, 1}
        np.testing.assert_array_equal(d, self._reference_digits(a, s, n))

    def test_closed_endpoint_hits_2_pow_31(self):
        # u = -1/2 exactly -> |v| = 2^31, one past int32: the two-limb
        # extraction must encode it as digit 1 at position 1 (value
        # 2^-1), where the int32 path would overflow
        a = np.array([[-2.0, 0.5, 0.0]], np.float32)   # max 2.0 -> scale 4
        d, s = sd_quantize(jnp.asarray(a), n=32, axis=-1)
        d, s = np.asarray(d), np.asarray(s)
        assert float(s[0, 0]) == 4.0
        want_first = np.zeros(32, np.int32)
        want_first[0] = -1                              # -1/2 = -2^-1
        np.testing.assert_array_equal(d[0, 0], want_first)
        np.testing.assert_array_equal(d, self._reference_digits(a, s, 32))

    @pytest.mark.parametrize("n", [24, 32])
    def test_roundtrip_within_half_ulp(self, rng, n):
        a = rng.standard_normal((8, 12)).astype(np.float32)
        d, s = sd_quantize(jnp.asarray(a), n=n, axis=1)
        w = 0.5 ** np.arange(1, n + 1)
        rec = (np.asarray(d) @ w) * np.asarray(s)
        assert np.max(np.abs(rec - a)) <= np.asarray(s).max() * 2.0 ** -(n + 1)

    def test_width_guard(self):
        with pytest.raises(ValueError, match="n <= 32"):
            sd_quantize_inkernel(jnp.ones((2, 4), jnp.float32), n=33)


class TestWideMatmulModes:
    SHAPES = [(5, 20, 3),    # all dims ragged
              (3, 7, 2),     # K < k_tile
              (1, 24, 5),    # GEMV, M=1
              (1, 16, 1),    # single output element
              (17, 40, 9)]   # multiple ragged output tiles

    @pytest.mark.parametrize("n_bits", [24, 32])
    @pytest.mark.parametrize("shape", SHAPES)
    def test_three_paths_bitwise(self, rng, n_bits, shape):
        M, K, N = shape
        x = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))
        fused = np.asarray(olm_matmul(x, w, n_bits=n_bits, use_pallas=True,
                                      quantize="kernel"))
        host = np.asarray(olm_matmul(x, w, n_bits=n_bits, use_pallas=True,
                                     quantize="host"))
        oracle = np.asarray(olm_matmul(x, w, n_bits=n_bits,
                                       use_pallas=False))
        np.testing.assert_array_equal(fused, host)
        np.testing.assert_array_equal(fused, oracle)

    @pytest.mark.parametrize("n_bits", [24, 32])
    def test_x64_scope_does_not_change_bits(self, rng, n_bits):
        # the x64 CI axis must see the same bits: wide decode rounds
        # the same exact value RN-even on the int64 and two-limb
        # branches, and the n = 32 oracle's auto enable_x64 scope is
        # equivalent to running inside an ambient one
        x = jnp.asarray(rng.standard_normal((4, 36)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((36, 5)).astype(np.float32))
        ambient = {use: np.asarray(olm_matmul(x, w, n_bits=n_bits,
                                              use_pallas=use))
                   for use in (True, False)}
        with enable_x64():
            scoped = {use: np.asarray(olm_matmul(x, w, n_bits=n_bits,
                                                 use_pallas=use))
                      for use in (True, False)}
        for use in (True, False):
            np.testing.assert_array_equal(ambient[use], scoped[use])
        np.testing.assert_array_equal(ambient[True], ambient[False])

    def test_n32_oracle_under_outer_jit(self, rng):
        # flipping x64 mid-trace would corrupt the enclosing trace's
        # loop carries, so the auto-scope must refuse inside an outer
        # jit without ambient x64 — and work under an ambient scope,
        # producing the same bits as the eager auto-scoped call; the
        # Pallas path needs no scope at all (int32 truncated datapath)
        x = jnp.asarray(rng.standard_normal((3, 20)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((20, 4)).astype(np.float32))
        step = jax.jit(lambda x, w: olm_matmul(x, w, n_bits=32,
                                               use_pallas=False))
        eager = np.asarray(olm_matmul(x, w, n_bits=32, use_pallas=False))
        if int64_enabled():       # the x64 CI axis: no refusal needed
            np.testing.assert_array_equal(np.asarray(step(x, w)), eager)
        else:
            with pytest.raises(ValueError, match="enable_x64"):
                step(x, w)
            with enable_x64():
                np.testing.assert_array_equal(np.asarray(step(x, w)), eager)
        pallas_step = jax.jit(lambda x, w: olm_matmul(x, w, n_bits=32,
                                                      use_pallas=True))
        np.testing.assert_array_equal(np.asarray(pallas_step(x, w)), eager)

    @pytest.mark.parametrize("mode", sorted(MATMUL_MODES.values()))
    @pytest.mark.parametrize("shape", SHAPES)
    def test_error_bound_vs_f64_every_mode(self, rng, mode, shape):
        M, K, N = shape
        n_bits = int(mode.removeprefix("olm"))
        x = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))
        got = np.asarray(olm_matmul_ref(x, w, n_bits=n_bits))
        exact = np.asarray(x, np.float64) @ np.asarray(w, np.float64)
        bound = np.asarray(olm_error_bound(x, w, n_bits=n_bits))
        assert np.all(np.abs(got - exact) <= bound)

    @pytest.mark.parametrize("n_bits", [24, 32])
    def test_wide_bound_includes_decode_term(self, rng, n_bits):
        # the wide bound must carry the (T + 1) * WIDE_DECODE_ULP
        # decode/accumulation rounding term on top of the bare
        # quantization ledger — exactly as documented
        from repro.kernels.common import pow2_scale
        from repro.kernels.online_dot.matmul import (ULP_PER_LANE,
                                                     WIDE_DECODE_ULP)
        x = rng.standard_normal((3, 32)).astype(np.float32)
        w = rng.standard_normal((32, 4)).astype(np.float32)
        bound = np.asarray(olm_error_bound(jnp.asarray(x), jnp.asarray(w),
                                           n_bits=n_bits))
        kt, T = 16, 2
        sx = np.asarray(pow2_scale(jnp.asarray(x.reshape(3, T, kt)),
                                   2))[..., 0]
        sw = np.asarray(pow2_scale(jnp.asarray(w.T.copy().reshape(4, T, kt)),
                                   2))[..., 0]
        per_lane = ULP_PER_LANE * 2.0 ** -n_bits + (T + 1) * WIDE_DECODE_ULP
        want = kt * np.float32(per_lane) * np.einsum("mt,nt->mn", sx, sw)
        np.testing.assert_allclose(bound, want, rtol=1e-6)


class TestCheckBenchTool:
    def test_tuning_invariant_check_runs(self):
        root = Path(__file__).resolve().parents[1]
        res = subprocess.run(
            [sys.executable, str(root / "tools" / "check_bench.py"),
             "--only", "tuning"],
            capture_output=True, text=True, cwd=root)
        assert res.returncode == 0, res.stdout + res.stderr
        assert "re-pin invariant holds" in res.stdout

    def test_tuning_check_rejects_broken_schema(self, tmp_path):
        root = Path(__file__).resolve().parents[1]
        bad = tmp_path / "tuning.json"
        bad.write_text('{"entries": {"m8n8k8b16": {"k_tile": "wide"}}}')
        res = subprocess.run(
            [sys.executable, str(root / "tools" / "check_bench.py"),
             "--only", "tuning", "--tuning", str(bad)],
            capture_output=True, text=True, cwd=root)
        assert res.returncode == 1
        assert "FAIL" in res.stdout
