"""The grid-tiled olm matmul kernel: operand reuse without changing a bit.

Three contracts:
  * bit-identity — the (M_tiles, N_tiles, K_tiles) Pallas kernel matches
    the broadcast jnp oracle bit-for-bit across block/k_tile sweeps,
    ragged shapes, the M=1 GEMV case, and every registered olm mode;
  * accumulator carry — the float32 accumulator carried across the K
    grid dimension reproduces the oracle's K-tile loop exactly;
  * operand traffic — digit-grid elements delivered to the compute body
    scale with M + N on the grid path (vs M*N broadcast), with reuse
    >= min(block_m, block_n)/2.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs.olm_array import MATMUL_MODES, MATMUL_TILING, engine_for
from repro.core.numerics import DotEngine
from repro.kernels.common import pow2_scale, sd_quantize
from repro.kernels.online_dot.matmul import (DEFAULT_BLOCK_M,
                                             DEFAULT_BLOCK_N,
                                             DEFAULT_K_TILE, digit_traffic,
                                             olm_error_bound, olm_matmul,
                                             olm_matmul_ref)


def _pair(rng, M, K, N):
    return (jnp.asarray(rng.standard_normal((M, K)).astype(np.float32)),
            jnp.asarray(rng.standard_normal((K, N)).astype(np.float32)))


class TestGridBitIdentity:
    # both Pallas operand formats must survive every sweep: "host"
    # (pre-expanded digit grids — the near-oracle reference kernel) and
    # "kernel" (the fused quantize-in-prologue default)
    @pytest.mark.parametrize("quantize", ["host", "kernel"])
    @pytest.mark.parametrize("block_m,block_n", [(1, 1), (2, 4), (4, 2),
                                                 (8, 8), (16, 3)])
    def test_block_sweep_bitwise(self, rng, quantize, block_m, block_n):
        x, w = _pair(rng, 9, 32, 11)   # ragged vs every tested block shape
        gp = np.asarray(olm_matmul(x, w, use_pallas=True, quantize=quantize,
                                   block_m=block_m, block_n=block_n))
        gr = np.asarray(olm_matmul_ref(x, w))
        np.testing.assert_array_equal(gp, gr)

    @pytest.mark.parametrize("quantize", ["host", "kernel"])
    @pytest.mark.parametrize("k_tile", [4, 8, 16])
    def test_k_tile_sweep_bitwise(self, rng, quantize, k_tile):
        x, w = _pair(rng, 5, 37, 6)    # ragged K: zero-padded last tile
        gp = np.asarray(olm_matmul(x, w, k_tile=k_tile, use_pallas=True,
                                   quantize=quantize))
        gr = np.asarray(olm_matmul_ref(x, w, k_tile=k_tile))
        np.testing.assert_array_equal(gp, gr)

    @pytest.mark.parametrize("quantize", ["host", "kernel"])
    def test_accumulator_carry_across_k_tiles(self, rng, quantize):
        # K = 4 tiles: the kernel's resident accumulator must replay the
        # oracle's tile-loop f32 additions exactly, and dropping the K
        # tiling (k_tile >= K would change the adder tree) must stay
        # within the documented bound
        x, w = _pair(rng, 6, 64, 7)
        gp = np.asarray(olm_matmul(x, w, k_tile=16, use_pallas=True,
                                   quantize=quantize))
        gr = np.asarray(olm_matmul_ref(x, w, k_tile=16))
        np.testing.assert_array_equal(gp, gr)
        exact = np.asarray(x) @ np.asarray(w)
        bound = np.asarray(olm_error_bound(x, w, k_tile=16))
        assert np.all(np.abs(gp - exact) <= bound)


class TestRaggedShapes:
    SHAPES = [(5, 20, 3),    # all of M, N ragged vs 8x8 blocks, K vs 16
              (3, 7, 2),     # K < k_tile
              (1, 24, 5),    # GEMV, M=1
              (1, 16, 1),    # single output element
              (17, 40, 9)]   # multiple ragged output tiles

    @pytest.mark.parametrize("mode", sorted(MATMUL_MODES.values()))
    @pytest.mark.parametrize("shape", SHAPES)
    def test_every_olm_mode_both_paths(self, rng, mode, shape):
        M, K, N = shape
        n_bits = int(mode.removeprefix("olm"))   # olm8..olm32
        x, w = _pair(rng, M, K, N)
        yp = np.asarray(DotEngine(mode=mode, use_pallas=True).dot(x, w))
        yr = np.asarray(DotEngine(mode=mode, use_pallas=False).dot(x, w))
        np.testing.assert_array_equal(yp, yr)
        exact = np.asarray(x) @ np.asarray(w)
        bound = np.asarray(olm_error_bound(x, w, n_bits=n_bits))
        assert np.all(np.abs(yr - exact) <= bound)

    def test_gemv_through_engine_for(self, rng):
        x, w = _pair(rng, 1, 48, 13)
        # default engine_for is autotuned per shape; tiling=None pins
        # the static paper-array MATMUL_TILING — both must match the
        # oracle bit for bit (tiling never changes numerics)
        assert engine_for(16, use_pallas=True).tiling == "auto"
        eng = engine_for(16, use_pallas=True, tiling=None)
        assert (eng.k_tile, eng.block_m, eng.block_n) == (
            MATMUL_TILING["k_tile"], MATMUL_TILING["block_m"],
            MATMUL_TILING["block_n"])
        want = np.asarray(olm_matmul_ref(x, w))
        for e in (eng, engine_for(16, use_pallas=True)):
            np.testing.assert_array_equal(np.asarray(e.dot(x, w)), want)


class TestZeroPadding:
    def test_all_zero_rows_give_exact_zero(self, rng):
        x, w = _pair(rng, 6, 20, 4)
        x = x.at[2].set(0.0)
        w = w.at[:, 1].set(0.0)
        for use in (True, False):
            got = np.asarray(olm_matmul(x, w, use_pallas=use))
            assert not got[2].any()      # zero row -> exactly zero row
            assert not got[:, 1].any()   # zero column -> exactly zero col

    def test_pow2_scale_zero_guard(self):
        a = jnp.zeros((3, 8), jnp.float32)
        s = np.asarray(pow2_scale(a, 1))
        np.testing.assert_array_equal(s, np.ones((3, 1), np.float32))
        d, s = sd_quantize(a, n=16, axis=1)
        assert not np.asarray(d).any()
        np.testing.assert_array_equal(np.asarray(s),
                                      np.ones((3, 1), np.float32))

    def test_padding_lanes_contribute_zero(self, rng):
        # K=17 pads 15 dead lanes into the second tile; their digit grids
        # must be all-zero so the padded matmul equals the K=32 matmul of
        # the explicitly zero-extended operands, bit for bit
        x, w = _pair(rng, 4, 17, 3)
        xz = jnp.pad(x, ((0, 0), (0, 15)))
        wz = jnp.pad(w, ((0, 15), (0, 0)))
        for use in (True, False):
            np.testing.assert_array_equal(
                np.asarray(olm_matmul(x, w, use_pallas=use)),
                np.asarray(olm_matmul(xz, wz, use_pallas=use)))


class TestOperandTraffic:
    def test_grid_scales_with_m_plus_n_not_mn(self):
        # Per output tile the kernel materializes block_m + block_n digit
        # grids, not block_m * block_n: with the whole output as one tile
        # (block = shape), doubling both dims doubles grid traffic while
        # broadcast traffic quadruples
        t1 = digit_traffic(32, 32, DEFAULT_K_TILE, block_m=32, block_n=32)
        t2 = digit_traffic(64, 64, DEFAULT_K_TILE, block_m=64, block_n=64)
        assert t1["grid_elems"] == (32 + 32) * DEFAULT_K_TILE * 16
        assert t2["grid_elems"] == 2 * t1["grid_elems"]          # ~ M + N
        assert t2["broadcast_elems"] == 4 * t1["broadcast_elems"]  # ~ M * N
        # fixed 8x8 blocks: traffic still down by the constant harmonic
        # reuse factor at every size
        for M, N in ((32, 32), (64, 64), (128, 128)):
            t = digit_traffic(M, N, DEFAULT_K_TILE)
            assert t["broadcast_elems"] == t["reuse"] * t["grid_elems"]
            assert t["reuse"] == 2 / (1 / DEFAULT_BLOCK_M +
                                      1 / DEFAULT_BLOCK_N)

    def test_reuse_factor_meets_floor(self):
        for M, N in ((64, 64), (128, 32), (8, 8)):
            t = digit_traffic(M, N, 32)
            assert t["reuse"] >= min(DEFAULT_BLOCK_M, DEFAULT_BLOCK_N) / 2
        # even blocks: harmonic mean, here exactly min(bm, bn)
        assert digit_traffic(64, 64, 32)["reuse"] == min(
            DEFAULT_BLOCK_M, DEFAULT_BLOCK_N)

    def test_traffic_counts_are_exact_elements(self):
        # M=N=block, one K tile: grid loads each grid once -> (M + N)*kt*n
        t = digit_traffic(8, 8, 16, n_bits=16)
        assert t["grid_elems"] == (8 + 8) * 16 * 16
        assert t["broadcast_elems"] == 2 * 8 * 8 * 16 * 16
        assert t["grid_bytes"] == 4 * t["grid_elems"]


class TestServingTilingOverride:
    def test_dot_tiling_reaches_engine(self):
        from repro.models.model import Model
        from repro.serving.engine import ServeEngine
        from repro.models.config import ModelConfig
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=16,
                          n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=512,
                          param_dtype="float32", compute_dtype="float32")
        model = Model(cfg, DotEngine(mode="native"))
        eng = ServeEngine(model, params=None, slots=1, max_len=8,
                          dot_mode="olm16",
                          dot_tiling={"block_m": 4, "block_n": 16,
                                      "k_tile": 8})
        assert eng.model.eng.mode == "olm16"
        assert eng.model.eng.block_m == 4
        assert eng.model.eng.block_n == 16
        assert eng.model.eng.k_tile == 8
        with pytest.raises(ValueError, match="unknown dot_tiling"):
            ServeEngine(model, params=None, slots=1, max_len=8,
                        dot_tiling={"block_q": 4})
