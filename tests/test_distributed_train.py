"""Distributed train-step correctness on the local device.

Key invariant: gradient accumulation over microbatches must equal the
single-batch gradient (the stride-preserving split reorders rows within
the batch, which is loss-invariant for mean reduction).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import use_mesh
from repro.configs import smoke_config
from repro.data.synthetic import SyntheticLMDataset
from repro.distributed.sharding import Sharder
from repro.distributed.train import (build_train_step, init_train_state,
                                     jit_train_step)
from repro.launch.mesh import make_local_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("internlm2_1_8b")
    model = Model(cfg)
    mesh = make_local_mesh()
    sharder = Sharder(mesh, cfg)
    sharder.set_batch(8)
    data = SyntheticLMDataset(cfg, 8, 32, seed=5)
    with use_mesh(mesh):
        state = init_train_state(model, jax.random.PRNGKey(0))
    return cfg, model, mesh, sharder, state, data


def _run(model, sharder, mesh, state, batch, **kw):
    with use_mesh(mesh):
        step = build_train_step(model, sharder,
                                opt_cfg=AdamWConfig(lr=1e-3), **kw)
        return step(state, batch)


class TestTrainStep:
    def test_loss_decreases(self, setup):
        cfg, model, mesh, sharder, state, data = setup
        with use_mesh(mesh):
            step = jit_train_step(model, sharder, state, ("tokens",),
                                  opt_cfg=AdamWConfig(lr=3e-3),
                                  schedule_total=30)
            s = jax.tree.map(jnp.copy, state)  # real copy: step donates arg 0
            batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
            losses = []
            for i in range(12):  # overfit one batch: must descend
                s, m = step(s, batch)
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.05

    def test_microbatch_equivalence(self, setup):
        cfg, model, mesh, sharder, state, data = setup
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        s1, m1 = _run(model, sharder, mesh, state, batch, microbatches=1)
        s2, m2 = _run(model, sharder, mesh, state, batch, microbatches=2)
        # same accumulated gradient => same updated params (fp tolerance)
        l1 = jax.tree_util.tree_leaves(s1["params"])
        l2 = jax.tree_util.tree_leaves(s2["params"])
        for a, b in zip(l1, l2):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-3, rtol=5e-3)

    def test_compressed_grads_still_learn(self, setup):
        cfg, model, mesh, sharder, state, data = setup
        batch = {k: jnp.asarray(v) for k, v in data.batch(1).items()}
        s, m = _run(model, sharder, mesh, state, batch, compress_grads=True)
        assert np.isfinite(float(m["loss"]))
        assert s["ef"] is not None  # error-feedback state materialized
