"""DotEngine mode registry + the olm matmul front-end.

The dispatch-layer contract: every registered mode is a drop-in matmul
numerics for the model stack; the olm modes lower float GEMM tiles
through the fused online inner-product array and must be (a) bit-identical
between the Pallas kernel path and the pure-jnp oracle and (b) inside the
documented ulp bound of the exact f32 matmul.
"""
import dataclasses
import re

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.numerics import DotEngine
from repro.kernels.common import sd_quantize
from repro.kernels.online_dot.matmul import (ULP_PER_LANE, olm_error_bound,
                                             olm_matmul, olm_matmul_ref)
from repro.models import layers
from repro.models.config import ModelConfig


def _mlp_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=16, n_heads=2,
                n_kv_heads=2, d_ff=32, vocab_size=512,
                param_dtype="float32", compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


class TestRegistry:
    def test_all_modes_registered(self):
        assert {"native", "tpmm8", "tpmm16",
                "olm8", "olm16", "olm24", "olm32"} <= set(DotEngine.modes())

    def test_unknown_mode_rejected_at_construction(self):
        with pytest.raises(ValueError, match="unknown DotEngine mode"):
            DotEngine(mode="tpmm12")

    def test_model_config_validates_dot_mode(self):
        with pytest.raises(ValueError, match="not a registered"):
            _mlp_cfg(dot_mode="bogus")
        assert _mlp_cfg(dot_mode="olm16").dot_mode == "olm16"

    def test_mode_table_documents_tradeoffs(self):
        for m in DotEngine.mode_table():
            assert m.summary and m.error and m.cost

    def test_duplicate_registration_rejected(self):
        from repro.core.numerics import register_mode
        with pytest.raises(ValueError, match="already registered"):
            register_mode("native", summary="x", error="x", cost="x")(
                lambda eng, x, w: x)

    def test_engine_for_helper(self):
        from repro.configs.olm_array import ARRAY_PRECISIONS, engine_for
        # every paper array precision is a servable matmul mode
        for n in ARRAY_PRECISIONS:
            assert engine_for(n).mode == f"olm{n}"
        with pytest.raises(ValueError):
            engine_for(12)


class TestSdQuantize:
    def test_roundtrip_within_half_ulp(self, rng):
        a = jnp.asarray(rng.standard_normal((8, 12)).astype(np.float32))
        d, s = sd_quantize(a, n=16, axis=1)
        assert set(np.unique(np.asarray(d))) <= {-1, 0, 1}
        w = 0.5 ** np.arange(1, 17)
        rec = (np.asarray(d) @ w) * np.asarray(s)
        assert np.max(np.abs(rec - np.asarray(a))) <= \
            np.asarray(s).max() * 2.0 ** -17 + 1e-9

    def test_matches_scalar_codec(self, rng):
        from repro.core.sd import frac_to_digits
        a = rng.uniform(-0.9, 0.9, (5,)).astype(np.float32)
        d, s = sd_quantize(jnp.asarray(a)[None, :], n=12, axis=1)
        d, s = np.asarray(d)[0], float(np.asarray(s)[0, 0])
        for i, v in enumerate(a):
            assert list(d[i]) == frac_to_digits(float(v) / s, 12)


class TestOlmMatmul:
    @pytest.mark.parametrize("n_bits", [8, 16])
    def test_pallas_bitwise_matches_oracle(self, rng, n_bits):
        # K=20 exercises the K-tile zero-padding path (k_tile=16)
        M, K, N = 4, 20, 3
        x = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))
        gp = np.asarray(olm_matmul(x, w, n_bits=n_bits, use_pallas=True,
                                   block_m=2, block_n=2))
        gr = np.asarray(olm_matmul_ref(x, w, n_bits=n_bits))
        np.testing.assert_array_equal(gp, gr)

    @pytest.mark.parametrize("n_bits", [8, 16])
    @pytest.mark.parametrize("shape", [(8, 32, 8), (3, 5, 2), (1, 16, 1)])
    def test_within_documented_ulp_bound(self, rng, n_bits, shape):
        M, K, N = shape
        x = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))
        got = np.asarray(olm_matmul_ref(x, w, n_bits=n_bits))
        exact = np.asarray(x) @ np.asarray(w)
        bound = np.asarray(olm_error_bound(x, w, n_bits=n_bits))
        assert np.all(np.abs(got - exact) <= bound)
        assert ULP_PER_LANE >= 3.0  # the ledger the bound documents

    def test_engine_dot_is_the_matmul_oracle(self, rng):
        x = jnp.asarray(rng.standard_normal((2, 3, 24)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((24, 5)).astype(np.float32))
        got = np.asarray(DotEngine(mode="olm16").dot(x, w))
        want = np.asarray(olm_matmul_ref(x.reshape(-1, 24), w))
        np.testing.assert_array_equal(got, want.reshape(2, 3, 5))

    def test_contraction_mismatch_raises(self, rng):
        x = jnp.zeros((2, 4), jnp.float32)
        w = jnp.zeros((5, 3), jnp.float32)
        with pytest.raises(ValueError, match="contraction mismatch"):
            olm_matmul(x, w)

    def test_decode_window_guard(self, rng):
        # n_bits=16, k_tile=64 -> stream 16 + 2*6 = 28: past the plain
        # f32 window, served exactly by the wide decode (was a refusal
        # before the n = 24/32 lowering landed) — still bit-identical
        # between kernel and oracle
        x = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((64, 2)).astype(np.float32))
        np.testing.assert_array_equal(
            np.asarray(olm_matmul(x, w, n_bits=16, k_tile=64,
                                  use_pallas=True)),
            np.asarray(olm_matmul(x, w, n_bits=16, k_tile=64,
                                  use_pallas=False)))
        # past the 48-digit wide window even the two-limb decode would
        # silently round; must refuse instead (n=32, k_tile=512 ->
        # stream 32 + 2*9 = 50)
        with pytest.raises(ValueError, match="decode window"):
            olm_matmul(jnp.zeros((2, 512), jnp.float32),
                       jnp.zeros((512, 2), jnp.float32),
                       n_bits=32, k_tile=512)


class TestMlpRoundTrip:
    @pytest.mark.parametrize("mode", sorted(DotEngine.modes()))
    def test_every_mode_runs_mlp(self, rng, mode):
        cfg = _mlp_cfg()
        p = layers.mlp_init(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(rng.standard_normal((2, 3, 16)).astype(np.float32))
        y = np.asarray(layers.mlp_apply(p, cfg, x, DotEngine(mode=mode)))
        y0 = np.asarray(layers.mlp_apply(p, cfg, x, DotEngine(mode="native")))
        assert y.shape == (2, 3, 16)
        assert np.isfinite(y).all()
        # digit modes at >= 16 working bits track the exact MLP closely
        # (24/32 are at or below f32 rounding); coarser working
        # precisions (8-bit modes, truncated olm{n}t{p} tiers below 16)
        # scale the tolerance by their working-digit count
        m = re.fullmatch(r"(?:olm|tpmm)(\d+)(?:t(\d+))?", mode)
        work = int(m.group(2) or m.group(1)) if m else 32
        tol = 0.0 if mode == "native" else \
            min(0.6, max(0.02, 0.6 * 2.0 ** (8 - work)))
        assert np.abs(y - y0).max() <= tol * max(np.abs(y0).max(), 1.0) + 1e-12

    def test_olm16_mlp_bit_identical_to_oracle(self, rng):
        """Acceptance: an end-to-end MLP forward under mode="olm16" on the
        fused kernel path is bit-identical to the same forward on the
        pure-jnp online-dot matmul oracle."""
        cfg = _mlp_cfg()
        p = layers.mlp_init(jax.random.PRNGKey(1), cfg)
        x = jnp.asarray(rng.standard_normal((2, 2, 16)).astype(np.float32))
        y_kernel = layers.mlp_apply(
            p, cfg, x, DotEngine(mode="olm16", use_pallas=True))
        y_oracle = layers.mlp_apply(
            p, cfg, x, DotEngine(mode="olm16", use_pallas=False))
        np.testing.assert_array_equal(np.asarray(y_kernel),
                                      np.asarray(y_oracle))


class TestWeightDtypeHandling:
    def test_digit_modes_keep_master_precision(self, rng):
        """fp32 master weights must reach the digit decomposition at full
        mantissa — not pre-rounded through the bf16 activation dtype."""
        from repro.kernels.tpmm.ops import tpmm
        x = jnp.asarray(rng.standard_normal((4, 32)), jnp.bfloat16)
        w32 = jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32))
        w32 = w32 * (1 + 1e-3 * rng.standard_normal((32, 8)).astype(np.float32))
        got = DotEngine(mode="tpmm16", use_pallas=False).dot(x, w32)
        want = tpmm(x, w32, use_pallas=False).astype(jnp.bfloat16)
        np.testing.assert_array_equal(
            np.asarray(got, np.float32), np.asarray(want, np.float32))
        degraded = tpmm(x, w32.astype(jnp.bfloat16).astype(jnp.float32),
                        use_pallas=False).astype(jnp.bfloat16)
        assert not np.array_equal(np.asarray(want, np.float32),
                                  np.asarray(degraded, np.float32))

    def test_native_mode_casts_to_compute_dtype(self, rng):
        x = jnp.asarray(rng.standard_normal((4, 8)), jnp.bfloat16)
        w = jnp.asarray(rng.standard_normal((8, 3)).astype(np.float32))
        y = DotEngine(mode="native").dot(x, w)
        assert y.dtype == jnp.bfloat16

    def test_output_dtype_follows_activations(self, rng):
        x = jnp.asarray(rng.standard_normal((2, 16)), jnp.bfloat16)
        w = jnp.asarray(rng.standard_normal((16, 4)).astype(np.float32))
        for mode in ("tpmm16", "olm16"):
            assert DotEngine(mode=mode).dot(x, w).dtype == jnp.bfloat16


class TestServingWiring:
    def test_engine_mode_override(self):
        from repro.models.model import Model
        from repro.serving.engine import ServeEngine
        cfg = _mlp_cfg(dot_mode="native")
        model = Model(cfg, DotEngine(mode="native", interpret=False,
                                     use_pallas=True))
        eng = ServeEngine(model, params=None, slots=1, max_len=8,
                          dot_mode="olm16")
        assert eng.model.eng.mode == "olm16"
        # deployment knobs survive the mode override
        assert eng.model.eng.interpret is False
        assert eng.model.eng.use_pallas is True
        assert eng.model.cfg is cfg
        same = ServeEngine(model, params=None, slots=1, max_len=8)
        assert same.model is model
