"""Substrate tests: data pipeline, checkpointing (incl. elastic restore),
optimizer, gradient compression, fault tolerance, serving engine."""
import json
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import smoke_config
from repro.data.synthetic import SyntheticLMDataset
from repro.distributed.fault import PreemptionGuard, StragglerWatchdog, retry_step
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compress_int8, decompress_int8, ef_compress_tree
from repro.optim.schedule import cosine_schedule


class TestData:
    def test_determinism_and_restart(self):
        cfg = smoke_config("internlm2_1_8b")
        d1 = SyntheticLMDataset(cfg, 8, 64, seed=3)
        d2 = SyntheticLMDataset(cfg, 8, 64, seed=3)
        np.testing.assert_array_equal(d1.batch(17)["tokens"], d2.batch(17)["tokens"])
        # restart mid-stream reproduces the stream
        it = d1.iterate(start_step=5)
        np.testing.assert_array_equal(next(it)["tokens"], d2.batch(5)["tokens"])

    def test_host_sharding_partitions_batch(self):
        cfg = smoke_config("internlm2_1_8b")
        full = SyntheticLMDataset(cfg, 8, 32, seed=1)
        parts = [SyntheticLMDataset(cfg, 8, 32, seed=1, process_index=i,
                                    process_count=4) for i in range(4)]
        assert all(p.local_batch == 2 for p in parts)
        # different hosts draw different tokens (independent slices)
        a, b = parts[0].batch(0)["tokens"], parts[1].batch(0)["tokens"]
        assert not np.array_equal(a, b)

    def test_frontend_stubs(self):
        cfg = smoke_config("seamless_m4t_medium")
        b = SyntheticLMDataset(cfg, 4, 16, seed=0).batch(0)
        assert b["frames"].shape == (4, cfg.n_frontend_tokens, cfg.d_model)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
        tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
        mgr.save(10, tree)
        out = mgr.restore(tree)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10.0))
        assert mgr.latest_step() == 10

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
        tree = {"x": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert mgr.all_steps() == [3, 4]

    def test_atomicity_no_tmp_visible(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=3, async_save=False)
        mgr.save(5, {"x": jnp.zeros(3)})
        assert not list(tmp_path.glob("*.tmp"))

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
        mgr.save(7, {"x": jnp.arange(5)})
        mgr.wait()
        assert mgr.latest_step() == 7

    def test_elastic_restore_new_mesh(self, tmp_path):
        # save replicated; restore with explicit (different) shardings
        from jax.sharding import NamedSharding, PartitionSpec as P
        mgr = CheckpointManager(tmp_path, keep=1, async_save=False)
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        mgr.save(1, tree)
        mesh = jax.make_mesh((1,), ("model",))
        sh = {"w": NamedSharding(mesh, P("model", None))}
        out = mgr.restore(tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(16.0).reshape(4, 4))
        assert out["w"].sharding == sh["w"]

    def test_structure_mismatch_raises(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=1, async_save=False)
        mgr.save(1, {"x": jnp.zeros(3)})
        with pytest.raises(ValueError):
            mgr.restore({"x": jnp.zeros(3), "y": jnp.zeros(2)})


class TestOptim:
    def test_adamw_descends_quadratic(self):
        params = {"w": jnp.array([2.0, -3.0])}
        opt = adamw_init(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, opt, _ = adamw_update(cfg, g, opt, params)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clip(self):
        params = {"w": jnp.ones(4)}
        opt = adamw_init(params)
        cfg = AdamWConfig(lr=1e-3, clip_norm=1.0)
        _, _, m = adamw_update(cfg, {"w": jnp.full(4, 1e6)}, opt, params)
        assert float(m["grad_norm"]) > 1e6  # reported pre-clip

    def test_schedule_shape(self):
        s = [float(cosine_schedule(jnp.asarray(t), warmup=10, total=100))
             for t in (0, 5, 10, 50, 100)]
        assert s[0] == 0.0 and s[1] == pytest.approx(0.5, abs=0.01)
        assert s[2] == pytest.approx(1.0, abs=0.01)
        assert s[4] == pytest.approx(0.1, abs=0.02)


class TestCompression:
    def test_int8_roundtrip_error_bounded(self, rng):
        g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        q, s = compress_int8(g)
        err = np.abs(np.asarray(decompress_int8(q, s)) - np.asarray(g))
        assert err.max() <= float(s) * 0.5 + 1e-7

    def test_error_feedback_unbiased(self, rng):
        # constant gradient: EF-compressed updates must sum to ~the truth
        g = {"w": jnp.asarray(rng.standard_normal(256) * 1e-3, jnp.float32)}
        ef = None
        acc = np.zeros(256)
        for _ in range(64):
            deq, ef = ef_compress_tree(g, ef)
            acc += np.asarray(deq["w"])
        want = np.asarray(g["w"]) * 64
        assert np.abs(acc - want).max() <= np.abs(np.asarray(g["w"])).max() + 1e-6


class TestFault:
    def test_watchdog_flags_outlier(self):
        wd = StragglerWatchdog(warmup_steps=5, z_threshold=3.0)
        for i in range(20):
            wd.observe(i, 0.1 + 0.001 * (i % 3))
        assert not wd.flagged
        assert wd.observe(20, 5.0)  # 50x step time
        assert wd.flagged == [20]

    def test_preemption_guard(self):
        with PreemptionGuard(signals=(signal.SIGUSR1,)) as g:
            assert not g.preempted
            os.kill(os.getpid(), signal.SIGUSR1)
            time.sleep(0.05)
            assert g.preempted

    def test_retry_step(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("transient collective failure")
            return 42

        assert retry_step(flaky, retries=3, backoff=0.01) == 42


class TestServing:
    def test_continuous_batching_e2e(self):
        from repro.serving.engine import Request, ServeEngine
        cfg = smoke_config("internlm2_1_8b")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, slots=3, max_len=48)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 10))).astype(np.int32),
                        max_new_tokens=6)
                for i in range(7)]
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert len(done) == 7
        assert all(len(r.output) == 6 for r in done)
        rep = ServeEngine.latency_report(done)
        assert rep["n"] == 7

    def test_engine_matches_offline_decode(self):
        """A single request through the engine equals prefill+decode."""
        from repro.serving.engine import Request, ServeEngine
        cfg = smoke_config("internlm2_1_8b")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        prompt = np.arange(5, dtype=np.int32) % cfg.vocab_size
        eng = ServeEngine(model, params, slots=2, max_len=32)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        done = eng.run()
        got = done[0].output
        # offline greedy
        cache = model.init_cache(1, 32)
        lg, cache, mem = model.prefill(
            params, {"tokens": jnp.asarray(prompt[None])}, cache)
        toks = [int(jnp.argmax(lg[0]))]
        pos = len(prompt)
        for _ in range(3):
            lg, cache = model.decode_step(
                params, jnp.asarray([toks[-1]]), jnp.asarray([pos]), cache, mem)
            toks.append(int(jnp.argmax(lg[0])))
            pos += 1
        assert got == toks
