"""Fused Pallas inner-product array vs the core oracle, plus compat shims.

The fused kernel must be bit-exact against core/inner_product.online_dot
(exact Python multiplier + streaming OnlineAdder tree) for every tested
(k, n, truncated) configuration — digits, not just values.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.inner_product import online_dot as oracle_dot
from repro.core.precision import OnlinePrecision
from repro.kernels.common import decode_stream
from repro.kernels.online_dot.ops import (dot_scale_log2, dot_stream_length,
                                          online_dot)
from repro.kernels.online_dot.ref import online_dot_batch_ref, tree_levels


def _digits(rng, B, K, n):
    return (rng.integers(-1, 2, size=(B, K, n)).astype(np.int32),
            rng.integers(-1, 2, size=(B, K, n)).astype(np.int32))


def _oracle_rows(xd, yd, cfg):
    B, K, _ = xd.shape
    return [oracle_dot([[int(v) for v in xd[b, i]] for i in range(K)],
                       [[int(v) for v in yd[b, i]] for i in range(K)], cfg)
            for b in range(B)]


class TestFusedKernel:
    @pytest.mark.parametrize("k", [1, 2, 3, 4, 5, 8])
    def test_small_k_vs_oracle_bitexact(self, rng, k):
        n, B = 8, 6
        xd, yd = _digits(rng, B, k, n)
        cfg = OnlinePrecision(n=n)
        z, val = online_dot(xd, yd, cfg, use_pallas=True, block_b=2)
        assert z.shape == (B, dot_stream_length(n, k))
        for b, r in enumerate(_oracle_rows(xd, yd, cfg)):
            assert r.digits == [int(v) for v in np.asarray(z)[b]]
            assert r.scale_log2 == dot_scale_log2(k)
            np.testing.assert_allclose(val[b], r.dot_value, atol=1e-12)

    @pytest.mark.parametrize("n", [16, 32])
    @pytest.mark.parametrize("k", [16, 64])
    def test_large_k_pallas_vs_ref(self, rng, n, k):
        B = 4
        xd, yd = _digits(rng, B, k, n)
        cfg = OnlinePrecision(n=n)
        zp, _ = online_dot(xd, yd, cfg, use_pallas=True, block_b=2)
        with compat.enable_x64(True):
            zr = online_dot_batch_ref(xd, yd, n=n)
            np.testing.assert_array_equal(np.asarray(zp), np.asarray(zr))

    @pytest.mark.parametrize("k", [2, 4, 7])
    def test_full_mode_vs_oracle(self, rng, k):
        n, B = 10, 4
        xd, yd = _digits(rng, B, k, n)
        cfg = OnlinePrecision(n=n, truncated=False, tail_gating=False)
        z, _ = online_dot(xd, yd, cfg, use_pallas=True, block_b=4)
        for b, r in enumerate(_oracle_rows(xd, yd, cfg)):
            assert r.digits == [int(v) for v in np.asarray(z)[b]]

    def test_value_accuracy_vs_exact_dot(self, rng):
        n, k, B = 16, 8, 32
        xd, yd = _digits(rng, B, k, n)
        cfg = OnlinePrecision(n=n)
        _, val = online_dot(xd, yd, cfg, use_pallas=True)
        w = 0.5 ** np.arange(1, n + 1)
        exact = ((xd @ w) * (yd @ w)).sum(axis=1)
        # each lane's product carries <= 1.1 ulp truncation; tree is exact
        assert np.max(np.abs(val - exact)) <= 1.1 * k * 2.0 ** -n

    def test_ref_fallback_matches_pallas(self, rng):
        n, k, B = 12, 4, 5
        xd, yd = _digits(rng, B, k, n)
        cfg = OnlinePrecision(n=n)
        zp, vp = online_dot(xd, yd, cfg, use_pallas=True, block_b=1)
        zr, vr = online_dot(xd, yd, cfg, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(zp), np.asarray(zr))
        np.testing.assert_array_equal(vp, vr)

    def test_int32_guard(self):
        cfg = OnlinePrecision(n=32, truncated=False, tail_gating=False)
        xd = np.zeros((4, 2, 32), np.int32)
        from repro.kernels.online_dot.kernel import online_dot_pallas
        with pytest.raises(ValueError):
            online_dot_pallas(xd, xd, n=32, truncated=False,
                              tail_gating=False, block_b=4)

    def test_stream_geometry(self):
        assert tree_levels(1) == 0
        assert tree_levels(2) == 1
        assert tree_levels(3) == 2
        assert tree_levels(256) == 8
        assert dot_stream_length(8, 1) == 8
        assert dot_stream_length(16, 8) == 22
        assert decode_stream(np.array([[1, 0, -1]]))[0] == 0.5 - 0.125


class TestCompat:
    """compat.py on the installed JAX version (whatever it is)."""

    def test_version_tuple(self):
        v = compat.jax_version()
        assert len(v) == 3 and all(isinstance(p, int) for p in v)

    def test_make_abstract_mesh(self):
        m = compat.make_abstract_mesh((16, 16), ("data", "model"))
        assert tuple(m.axis_names) == ("data", "model")
        assert tuple(m.axis_sizes) == (16, 16)
        with pytest.raises(ValueError):
            compat.make_abstract_mesh((16,), ("data", "model"))

    def test_enable_x64_scope(self):
        with compat.enable_x64(True):
            assert jnp.arange(2, dtype=jnp.int64).dtype == jnp.int64

    def test_use_mesh_context(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with compat.use_mesh(mesh):
            assert float(jnp.ones((2, 2)).sum()) == 4.0

    def test_shardings_for_resolves_specs(self):
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        tree = {"a": P("data"), "b": None, "c": [P(), P(None, "model")]}
        out = compat.shardings_for(mesh, tree)
        assert isinstance(out["a"], NamedSharding)
        assert out["a"].spec == P("data")
        assert out["b"] is None
        assert all(isinstance(s, NamedSharding) for s in out["c"])
