"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each assigned arch: one forward pass + one grad step asserting output
shapes and no NaNs, plus prefill+decode == full forward consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.models.model import Model, lm_loss

KEY = jax.random.PRNGKey(1)


def _batch(cfg, B=2, S=12):
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(KEY, (B, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(KEY, (B, cfg.n_frontend_tokens, cfg.d_model))
    return b


@pytest.fixture(scope="module", params=list_archs())
def arch_setup(request):
    cfg = smoke_config(request.param)
    m = Model(cfg)
    params = m.init(KEY)
    return request.param, cfg, m, params


class TestSmoke:
    def test_forward_shapes_no_nan(self, arch_setup):
        arch, cfg, m, params = arch_setup
        B, S = 2, 12
        batch = _batch(cfg, B, S)
        logits, aux = m.forward(params, batch)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert not np.isnan(np.asarray(logits)).any()
        assert np.isfinite(float(aux))

    def test_train_step_grads_finite(self, arch_setup):
        arch, cfg, m, params = arch_setup
        batch = _batch(cfg)
        loss, metrics = lm_loss(m, params, batch)
        assert np.isfinite(float(loss))
        g = jax.grad(lambda p: lm_loss(m, p, batch)[0])(params)
        leaves = jax.tree_util.tree_leaves(g)
        assert leaves and all(np.isfinite(np.asarray(l)).all() for l in leaves)

    def test_decode_matches_forward(self, arch_setup):
        arch, cfg, m, params = arch_setup
        B, S = 2, 12
        batch = _batch(cfg, B, S)
        logits, _ = m.forward(params, batch)
        cache = m.init_cache(B, max_len=S + 4)
        pf = {**batch, "tokens": batch["tokens"][:, :S - 1]}
        lg_p, cache, memory = m.prefill(params, pf, cache)
        lg_d, cache = m.decode_step(
            params, batch["tokens"][:, S - 1],
            jnp.full((B,), S - 1, jnp.int32), cache, memory)
        scale = np.abs(np.asarray(logits)).max()
        assert np.max(np.abs(np.asarray(lg_p) - np.asarray(logits[:, S - 2]))) / scale < 2e-2
        assert np.max(np.abs(np.asarray(lg_d) - np.asarray(logits[:, S - 1]))) / scale < 2e-2


class TestFullConfigs:
    def test_param_counts_match_published(self):
        # analytic counts land near the published sizes
        expect = {
            "qwen3_moe_235b_a22b": 235e9, "mixtral_8x22b": 141e9,
            "recurrentgemma_9b": 9.6e9, "chatglm3_6b": 6.2e9,
            "qwen1_5_110b": 111e9, "internlm2_1_8b": 1.9e9,
            "yi_34b": 34.4e9, "seamless_m4t_medium": 0.7e9,
            "mamba2_130m": 0.13e9, "llama_3_2_vision_11b": 10.6e9,
        }
        for a, want in expect.items():
            got = get_config(a).param_count()
            assert abs(got - want) / want < 0.25, (a, got, want)

    def test_long_context_archs_are_subquadratic(self):
        # long_500k only runs for archs with bounded attention state
        for a in ("mixtral_8x22b", "recurrentgemma_9b", "mamba2_130m"):
            cfg = get_config(a)
            assert cfg.sliding_window is not None or cfg.family == "ssm"


class TestFlashAttention:
    def test_flash_equals_plain(self, rng):
        from repro.models.layers import _attn_flash, _attn_plain
        B, S, H, D = 2, 64, 6, 16
        q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        qpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        kpos = jnp.arange(S)
        for causal, window in [(True, None), (True, 16), (False, None)]:
            a = _attn_plain(q, k, v, qpos, kpos, causal=causal, window=window)
            b = _attn_flash(q, k, v, qpos, kpos, causal=causal,
                            window=window, chunk=16)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-5, rtol=2e-5)

    def test_flash_with_empty_slots(self, rng):
        from repro.models.layers import _attn_flash, _attn_plain
        B, S, H, D, T = 1, 4, 2, 8, 32
        q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
        kpos = jnp.where(jnp.arange(T) < 20, jnp.arange(T), -1)  # 12 empty
        qpos = jnp.broadcast_to(jnp.arange(16, 20)[None], (B, S))
        a = _attn_plain(q, k, v, qpos, kpos, causal=True, window=None)
        b = _attn_flash(q, k, v, qpos, kpos, causal=True, window=None, chunk=8)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    def test_gqa_repeat_equals_grouped(self, rng):
        # flat-head (repeated-kv) attention == reference grouped GQA math
        from repro.models.layers import _attn_core
        B, S, Hq, Hkv, D = 2, 16, 6, 2, 8
        q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
        qpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        out = _attn_core(q, k, v, qpos, jnp.arange(S), causal=True, window=None)
        # reference grouped computation
        G = Hq // Hkv
        qg = np.asarray(q).reshape(B, S, Hkv, G, D)
        sc = np.einsum("bskgd,btkd->bkgst", qg, np.asarray(k)) / np.sqrt(D)
        mask = np.tril(np.ones((S, S), bool))
        sc = np.where(mask, sc, -1e30)
        w = np.exp(sc - sc.max(-1, keepdims=True))
        w = w / w.sum(-1, keepdims=True)
        ref = np.einsum("bkgst,btkd->bskgd", w, np.asarray(v)).reshape(B, S, Hq, D)
        np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5)
