import os

import numpy as np
import pytest

# NOTE: do NOT set XLA_FLAGS device-count here — smoke tests and benches
# must see the single real CPU device; only launch/dryrun.py forces 512.


@pytest.fixture(scope="session", autouse=True)
def _x64_scope():
    """REPRO_TEST_X64=1 runs the whole tier-1 suite inside the
    repro.compat.enable_x64 scope (the CI x64 matrix axis): the wide
    stream decode then takes its int64-accumulator branch and the n = 32
    oracle runs without the front-end's own enable_x64 wrap — every
    bit-identity assertion must hold either way, which is exactly the
    cross-x64 invariant the wide decode documents. Going through the
    compat shim (jax.experimental.enable_x64 on 0.4.x, jax.enable_x64 on
    0.6+) also exercises the shim itself on both CI JAX versions."""
    if os.environ.get("REPRO_TEST_X64") == "1":
        from repro.compat import enable_x64
        with enable_x64():
            yield
    else:
        yield


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)
