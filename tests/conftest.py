import os

import numpy as np
import pytest

# NOTE: do NOT set XLA_FLAGS device-count here unconditionally — smoke
# tests and benches must see the single real CPU device by default;
# launch/dryrun.py forces 512 for itself. The one sanctioned opt-in is
# REPRO_TEST_DEVICES=N (the CI `distributed` job sets 8): it forces N
# host devices for the whole pytest process so tests/
# test_distributed_matmul.py can build a real multi-device mesh. This
# must run at conftest import time, before anything imports jax — safe
# here because this module imports only os/numpy/pytest.
_n_dev = os.environ.get("REPRO_TEST_DEVICES", "")
if _n_dev.isdigit() and int(_n_dev) > 1:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={int(_n_dev)}").strip()


@pytest.fixture(scope="session", autouse=True)
def _x64_scope():
    """REPRO_TEST_X64=1 runs the whole tier-1 suite inside the
    repro.compat.enable_x64 scope (the CI x64 matrix axis): the wide
    stream decode then takes its int64-accumulator branch and the n = 32
    oracle runs without the front-end's own enable_x64 wrap — every
    bit-identity assertion must hold either way, which is exactly the
    cross-x64 invariant the wide decode documents. Going through the
    compat shim (jax.experimental.enable_x64 on 0.4.x, jax.enable_x64 on
    0.6+) also exercises the shim itself on both CI JAX versions."""
    if os.environ.get("REPRO_TEST_X64") == "1":
        from repro.compat import enable_x64
        with enable_x64():
            yield
    else:
        yield


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)
