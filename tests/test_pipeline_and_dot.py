"""Pipelined multiplier array, online adder, inner products, hw model."""
import math

import numpy as np
import pytest

from repro.core.hwmodel import (
    PAPER_TABLE1,
    array_multiplier_cost,
    nonpipelined_online_cost,
    online_multiplier_cost,
    serial_parallel_cost,
)
from repro.core.inner_product import online_dot, online_dot_pipelined
from repro.core.online_add import online_add
from repro.core.online_mul import online_multiply
from repro.core.pipeline import run_pipeline
from repro.core.precision import OnlinePrecision
from repro.core.sd import digits_to_frac


def _rand_pairs(rng, k, n):
    return [
        ([int(d) for d in rng.integers(-1, 2, size=n)],
         [int(d) for d in rng.integers(-1, 2, size=n)])
        for _ in range(k)
    ]


class TestOnlineAdder:
    def test_exact_randomized(self, rng):
        for _ in range(500):
            n = int(rng.integers(2, 24))
            a = [int(d) for d in rng.integers(-1, 2, size=n)]
            b = [int(d) for d in rng.integers(-1, 2, size=n)]
            out = online_add(a, b)
            assert abs(digits_to_frac(out) - (digits_to_frac(a) + digits_to_frac(b)) / 2) < 1e-12
            assert all(d in (-1, 0, 1) for d in out)


class TestPipeline:
    @pytest.mark.parametrize("n,k", [(8, 8), (8, 1), (16, 5), (24, 3)])
    def test_cycle_count_table3(self, rng, n, k):
        # paper Table III: (n + delta + 1) + (k - 1)
        cfg = OnlinePrecision(n=n)
        run = run_pipeline(_rand_pairs(rng, k, n), cfg)
        assert run.cycles == (n + 3 + 1) + (k - 1)

    def test_pipeline_matches_reference(self, rng):
        cfg = OnlinePrecision(n=12)
        pairs = _rand_pairs(rng, 6, 12)
        run = run_pipeline(pairs, cfg)
        for (x, y), tr in zip(pairs, run.traces):
            ref = online_multiply(x, y, cfg)
            assert tr.z_digits == ref.z_digits
            assert tr.z_int == ref.z_int

    def test_activity_reduced_vs_full(self, rng):
        pairs = _rand_pairs(rng, 16, 16)
        full = run_pipeline(pairs, OnlinePrecision(n=16, truncated=False, tail_gating=False))
        red = run_pipeline(pairs, OnlinePrecision(n=16))
        assert sum(red.active_slices_per_cycle) < 0.75 * sum(full.active_slices_per_cycle)
        assert red.flips_total < full.flips_total


class TestInnerProduct:
    @pytest.mark.parametrize("k", [2, 3, 8])
    def test_dot_value(self, rng, k):
        n = 10
        pairs = _rand_pairs(rng, k, n)
        xs = [p[0] for p in pairs]
        ys = [p[1] for p in pairs]
        r = online_dot_pipelined(xs, ys)
        want = sum(digits_to_frac(x) * digits_to_frac(y) for x, y in zip(xs, ys))
        # each product is <= 1.1 ulp @ 2^-n; adder tree is exact
        assert abs(r.dot_value - want) <= 1.2 * k * 2.0 ** -n
        assert r.online_delay == 3 + 2 * math.ceil(math.log2(max(k, 2)))

    def test_pipelined_equals_functional(self, rng):
        n, k = 8, 4
        pairs = _rand_pairs(rng, k, n)
        xs, ys = [p[0] for p in pairs], [p[1] for p in pairs]
        assert online_dot(xs, ys).digits == online_dot_pipelined(xs, ys).digits


class TestHwModel:
    def test_savings_trend_increases_with_n(self):
        # paper: savings grow with precision (Table I)
        saves = []
        for n in (8, 16, 24, 32):
            full = online_multiplier_cost(OnlinePrecision(n=n, truncated=False, tail_gating=False))
            red = online_multiplier_cost(OnlinePrecision(n=n))
            saves.append(1 - red.area / full.area)
        assert all(saves[i] < saves[i + 1] for i in range(len(saves) - 1))
        assert 0.15 < saves[0] < 0.35 and 0.30 < saves[-1] < 0.50

    def test_savings_within_paper_band(self):
        # Model savings land within +-15pp of the paper's synthesis.
        # The model is conservative: its "full" baseline uses the natural
        # register-fill ramp, whereas the paper's conventional design keeps
        # all n slices live in every stage (Fig. 5), and the paper's own
        # n=16 row is internally inconsistent (1734->976 latches = 43.7%
        # raw vs 31.93% quoted) -- see EXPERIMENTS.md.
        for n in (8, 16, 24, 32):
            full = online_multiplier_cost(OnlinePrecision(n=n, truncated=False, tail_gating=False))
            red = online_multiplier_cost(OnlinePrecision(n=n))
            got = 100 * (1 - red.area / full.area)
            paper = 100 * (1 - PAPER_TABLE1["area"]["reduced"][n] / PAPER_TABLE1["area"]["full"][n])
            assert abs(got - paper) < 15.0, (n, got, paper)

    def test_table2_orderings(self):
        # pipelined designs cost more area than iterative ones, truncated
        # less than full; non-pipelined online ~ serial-parallel class
        n = 8
        sp = serial_parallel_cost(n)
        ar = array_multiplier_cost(n)
        ol = nonpipelined_online_cost(n)
        fu = online_multiplier_cost(OnlinePrecision(n=n, truncated=False, tail_gating=False))
        re_ = online_multiplier_cost(OnlinePrecision(n=n))
        assert re_.area < fu.area
        assert max(sp.area, ar.area, ol.area) < re_.area
        assert sp.latches < re_.latches < fu.latches


class TestCycleFormulas:
    def test_table3(self):
        # all five rows of paper Table III for k=8
        k = 8
        rows = {
            "serial-parallel": lambda n: (n + 1) * k,
            "array": lambda n: n * k,
            "online": lambda n: (n + 3 + 1) * k,
            "pipelined": lambda n: (n + 3 + 1) + (k - 1),
        }
        paper = {
            "serial-parallel": {8: 72, 16: 136, 24: 200, 32: 264},
            "array": {8: 64, 16: 128, 24: 192, 32: 256},
            "online": {8: 96, 16: 160, 24: 224, 32: 288},
            "pipelined": {8: 19, 16: 27, 24: 35, 32: 43},
        }
        for name, f in rows.items():
            for n in (8, 16, 24, 32):
                assert f(n) == paper[name][n], (name, n)
