"""Fault-injection harness tests (Issue 9): seeded plan determinism,
replay-with-faults determinism and per-family recovery contracts, the
transient-prefill retry/backoff path, and unit tests of the
check_bench chaos / wall-clock gates."""
import json
import os
import sys

import jax
import numpy as np
import pytest

from repro.analysis import ast_lint
from repro.core.numerics import DotEngine
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.serving.engine import Request, ServeEngine
from repro.serving.faults import (FaultConfig, FaultInjector,
                                  TransientPrefillError, build_fault_plan)
from repro.serving.replay import ReplayConfig, build_workload, run_replay

VOCAB = 512


def _tiny_cfg():
    return ModelConfig(name="t", family="dense", n_layers=2, d_model=16,
                       n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=VOCAB,
                       param_dtype="float32", compute_dtype="float32")


@pytest.fixture(scope="module")
def tiny():
    model = Model(_tiny_cfg(), DotEngine())
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompt(n, seed=0):
    return np.random.default_rng(seed).integers(1, VOCAB, n) \
        .astype(np.int32)


# ------------------------------------------------------------- fault plans


class TestFaultPlan:
    def test_seeded_plan_deterministic(self):
        cfg = FaultConfig(seed=7, horizon_steps=40, n_exhaust=2,
                          n_corrupt=2, n_nan=2, n_prefill_fail=2)
        a, b = build_fault_plan(cfg), build_fault_plan(cfg)
        assert a == b
        assert len(a) == 8
        assert a == sorted(a, key=lambda e: (e["step"], e["kind"]))
        assert all(2 <= e["step"] < 40 for e in a)

    def test_different_seeds_differ(self):
        a = build_fault_plan(FaultConfig(seed=0, n_exhaust=4, n_corrupt=4,
                                         n_nan=4, n_prefill_fail=4))
        b = build_fault_plan(FaultConfig(seed=1, n_exhaust=4, n_corrupt=4,
                                         n_nan=4, n_prefill_fail=4))
        assert a != b

    def test_attach_requires_numerics_check_for_nan(self, tiny):
        model, params = tiny
        eng = ServeEngine(model, params, slots=1, max_len=16)
        inj = FaultInjector(build_fault_plan(FaultConfig()))
        with pytest.raises(ValueError, match="numerics_check"):
            inj.attach(eng)
        ok = ServeEngine(model, params, slots=1, max_len=16,
                         numerics_check=True)
        inj.attach(ok)
        assert ok.logits_tap is not None and ok.prefill_fault is not None

    def test_unknown_fault_kind_rejected(self):
        inj = FaultInjector([{"kind": "zap", "step": 0}])
        with pytest.raises(ValueError, match="unknown fault kind"):
            inj.apply(None, 0)


# ----------------------------------------------- transient prefill retries


class TestPrefillRetry:
    def test_retry_then_bit_identical(self, tiny):
        model, params = tiny
        kw = dict(slots=1, max_len=16, kv_block_size=4)
        clean = ServeEngine(model, params, **kw)
        clean.submit(Request(rid=0, prompt=_prompt(4), max_new_tokens=4))
        ref = clean.run()[0]

        eng = ServeEngine(model, params, prefill_retries=3,
                          prefill_backoff=1, **kw)
        budget = {"n": 2}

        def gate(step, reqs):
            if budget["n"] > 0:
                budget["n"] -= 1
                raise TransientPrefillError("injected")

        eng.prefill_fault = gate
        eng.submit(Request(rid=0, prompt=_prompt(4), max_new_tokens=4))
        done = eng.run()
        assert done[0].finish_reason == "length"
        assert done[0].n_retries == 2
        assert eng.counters["prefill_retries"] == 2
        # the retried prefill restarts from scratch: tokens identical
        assert done[0].output == ref.output
        assert eng.free_blocks == eng.kv_blocks - 1

    def test_exhausted_retries_finish_failed(self, tiny):
        model, params = tiny
        eng = ServeEngine(model, params, slots=1, max_len=16,
                          kv_block_size=4, prefill_retries=1,
                          prefill_backoff=1)

        def gate(step, reqs):
            raise TransientPrefillError("always down")

        eng.prefill_fault = gate
        eng.submit(Request(rid=0, prompt=_prompt(4), max_new_tokens=4))
        done = eng.run()
        assert done[0].finish_reason == "failed"
        assert done[0].output == []
        assert done[0].n_retries == 2       # initial + 1 retry allowance
        assert eng.kv_report()["integrity_ok"]


# --------------------------------------------------- replay under faults


class TestFaultedReplay:
    """One seeded workload driven fault-free, then twice under the same
    fault plan: the faulted runs must match each other byte for byte,
    and every fault must resolve per the recovery contract."""

    WORKLOAD = ReplayConfig(seed=0, n_requests=8, prompt_len_range=(3, 8),
                            max_new_range=(3, 6), vocab=VOCAB)

    def _engine(self, tiny):
        model, params = tiny
        return ServeEngine(model, params, slots=2, max_len=32,
                           kv_block_size=4, kv_blocks=9, max_queue=8,
                           numerics_check=True, integrity_audit=True)

    def test_deterministic_and_recovers(self, tiny):
        wl = build_workload(self.WORKLOAD)
        ref_done, ref_rep = run_replay(self._engine(tiny), wl)
        ref = {r.rid: r for r in ref_done}
        fcfg = FaultConfig(seed=0,
                           horizon_steps=max(10,
                                             int(ref_rep["steps_total"])
                                             * 2 // 3),
                           exhaust_blocks=8, exhaust_hold_steps=4)

        def go():
            eng = self._engine(tiny)
            inj = FaultInjector(build_fault_plan(fcfg))
            done, rep = run_replay(eng, wl, faults=inj)
            rep.pop("wall_s")
            return eng, inj, {r.rid: r for r in done}, rep

        eng1, inj1, d1, rep1 = go()
        eng2, inj2, d2, rep2 = go()
        # determinism: same plan + same workload -> same resolution
        assert inj1.summary() == inj2.summary()
        assert rep1 == rep2
        assert dict(eng1.counters) == dict(eng2.counters)
        for rid in d1:
            assert d1[rid].output == d2[rid].output
            assert d1[rid].finish_reason == d2[rid].finish_reason

        # every family actually fired against this workload
        stats = inj1.summary()
        for fam in ("exhaust", "corrupt", "nan", "prefill_fail"):
            assert stats.get(fam, 0) >= 1, stats

        # recovery bookkeeping balances: injected == resolved
        assert len(d1) == len(wl)
        assert rep1["n_numerics"] == stats["nan"]
        assert eng1.counters["table_repairs"] == stats["corrupt"]
        assert eng1.counters["prefill_retries"] == stats["prefill_fail"]
        assert eng1.counters["preempted"] >= 1

        # token-level contract per request
        known = {"eos", "length", "max_len", "cache_full", "deadline",
                 "rejected", "numerics", "failed"}
        for rid, r in d1.items():
            assert r.finish_reason in known
            b = ref[rid]
            if r.finish_reason == "numerics":
                # clean prefix: the poisoned token never lands
                assert r.output == b.output[:len(r.output)]
            elif r.finish_reason == b.finish_reason:
                # recovered (preempted / retried / repaired) or untouched
                # requests are bit-identical to the fault-free run
                assert r.output == b.output, rid
        untouched = [r for r in d1.values()
                     if r.n_preempts == 0 and r.n_retries == 0
                     and r.finish_reason != "numerics"]
        assert untouched, "fault plan touched every request"

        # nothing leaked: pool fully returned, shadow state consistent
        kvr = eng1.kv_report()
        assert kvr["integrity_ok"] and kvr["kv_blocks_held"] == 0
        assert kvr["kv_blocks_free"] == kvr["kv_blocks_usable"]

    def test_workload_robustness_knobs(self):
        cfg = ReplayConfig(seed=0, n_requests=6, deadline_every=2,
                           deadline_steps=9, priority_levels=3,
                           vocab=VOCAB)
        wl = build_workload(cfg)
        assert [w.get("deadline_steps") for w in wl] == \
            [None, 9, None, 9, None, 9]
        assert [w["priority"] for w in wl] == [0, 1, 2, 0, 1, 2]
        # defaults keep pre-existing seeded workloads byte-identical
        plain = build_workload(ReplayConfig(seed=0, n_requests=6,
                                            vocab=VOCAB))
        for w, p in zip(wl, plain):
            assert w["arrival_step"] == p["arrival_step"]
            np.testing.assert_array_equal(w["prompt"], p["prompt"])
            assert w["max_new"] == p["max_new"]
            assert "deadline_steps" not in p and "priority" not in p


# ------------------------------------------------- check_bench fault gates


def _check_bench():
    tools_dir = os.path.join(ast_lint._REPO_ROOT, "tools")
    if tools_dir not in sys.path:
        sys.path.insert(0, tools_dir)
    import check_bench
    return check_bench


def _faults_rows():
    vals = dict(completed=20, steps_total=48, injected_exhaust=1,
                injected_corrupt=1, injected_nan=1, injected_prefill_fail=1,
                preempted=3, table_repairs=1, prefill_retries=1, degraded=4,
                n_deadline=2, n_rejected=0, n_numerics=1, n_cache_full=0,
                identical_to_ref=19)
    return [{"op": f"serve_faults/s{seed}/{op}", "derived": v}
            for seed in (0, 1) for op, v in vals.items()]


def _write_bench(dirpath, name, rows):
    os.makedirs(dirpath, exist_ok=True)
    with open(os.path.join(dirpath, name), "w") as f:
        json.dump({"rows": rows}, f)


class TestCheckFaults:
    def test_committed_baseline_passes(self):
        cb = _check_bench()
        cb.check_faults(os.path.join(ast_lint._REPO_ROOT, "results",
                                     "baseline"))

    def test_synthetic_rows_pass(self, tmp_path):
        cb = _check_bench()
        _write_bench(tmp_path, "BENCH_serve_faults.json", _faults_rows())
        cb.check_faults(str(tmp_path))

    def test_unfired_family_rejected(self, tmp_path):
        cb = _check_bench()
        rows = _faults_rows()
        for r in rows:
            if r["op"] == "serve_faults/s1/injected_exhaust":
                r["derived"] = 0
        _write_bench(tmp_path, "BENCH_serve_faults.json", rows)
        with pytest.raises(cb.CheckFailure, match="must.*actually fire"):
            cb.check_faults(str(tmp_path))

    def test_unresolved_fault_rejected(self, tmp_path):
        cb = _check_bench()
        rows = _faults_rows()
        for r in rows:
            if r["op"] == "serve_faults/s0/n_numerics":
                r["derived"] = 0            # injected_nan stays 1
        _write_bench(tmp_path, "BENCH_serve_faults.json", rows)
        with pytest.raises(cb.CheckFailure, match="did not resolve"):
            cb.check_faults(str(tmp_path))

    def test_missing_row_rejected(self, tmp_path):
        cb = _check_bench()
        rows = [r for r in _faults_rows()
                if r["op"] != "serve_faults/s0/preempted"]
        _write_bench(tmp_path, "BENCH_serve_faults.json", rows)
        with pytest.raises(cb.CheckFailure, match="missing rows"):
            cb.check_faults(str(tmp_path))


def _replay_rows(us):
    return [
        {"op": "serve_replay/ttft_p50", "derived": 1.0},
        {"op": "serve_replay/ttft_p99", "derived": 2.0},
        {"op": "serve_replay/e2e_p50", "derived": 5.0},
        {"op": "serve_replay/e2e_p99", "derived": 9.0},
        {"op": "serve_replay/tokens_per_step", "derived": 1.5, "us": us},
        {"op": "serve_replay/completed", "derived": 10},
        {"op": "serve_replay/cache_full", "derived": 0},
        {"op": "serve_replay/prefill_compiles", "derived": 3},
        {"op": "serve_replay/blocks_peak", "derived": 5},
        {"op": "serve_replay/kv_paged", "derived": 0,
         "bytes_moved": 1000, "bytes_float": 2000},
        {"op": "serve_replay/kv_contig", "derived": 0,
         "bytes_moved": 4000},
    ]


class TestWallClockGate:
    def _dirs(self, tmp_path, fresh_us, base_us):
        bench, base = tmp_path / "bench", tmp_path / "baseline"
        _write_bench(bench, "BENCH_serve_replay.json",
                     _replay_rows(fresh_us))
        _write_bench(base, "BENCH_serve_replay.json",
                     _replay_rows(base_us))
        return str(bench), str(base)

    def test_off_by_default_ignores_wall_regression(self, tmp_path,
                                                    monkeypatch):
        cb = _check_bench()
        monkeypatch.delenv("REPRO_REPLAY_WALLCLOCK", raising=False)
        bench, base = self._dirs(tmp_path, 10_000_000, 1_000_000)
        cb.check_serving(bench, base, wall_tol=0.5)  # no raise

    def test_opt_in_catches_regression(self, tmp_path, monkeypatch):
        cb = _check_bench()
        monkeypatch.setenv("REPRO_REPLAY_WALLCLOCK", "1")
        bench, base = self._dirs(tmp_path, 10_000_000, 1_000_000)
        with pytest.raises(cb.CheckFailure, match="wall-clock regression"):
            cb.check_serving(bench, base, wall_tol=0.5)

    def test_opt_in_passes_within_tolerance(self, tmp_path, monkeypatch):
        cb = _check_bench()
        monkeypatch.setenv("REPRO_REPLAY_WALLCLOCK", "1")
        bench, base = self._dirs(tmp_path, 1_200_000, 1_000_000)
        cb.check_serving(bench, base, wall_tol=0.5)  # no raise
