"""Sharding-rule validation for every full architecture (shape-only).

Uses eval_shape (no allocation) + an abstract 16x16 mesh to assert that
every param/cache leaf's PartitionSpec divides its dimensions — the exact
property the dry-run needs to compile. Fast enough for CI because nothing
touches devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_abstract_mesh
from repro.configs import get_config, list_archs
from repro.distributed.sharding import Sharder, _path_str
from repro.models.model import Model

AbstractMesh = getattr(jax.sharding, "AbstractMesh", None)


def _mesh(multi_pod=False):
    if multi_pod:
        return make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_abstract_mesh((16, 16), ("data", "model"))


def _axis_size(mesh, name):
    return dict(zip(mesh.axis_names, mesh.axis_sizes))[name]


def _check_tree(sharder, tree, spec_fn, mesh):
    bad = []

    def visit(path, leaf):
        spec = spec_fn(_path_str(path), leaf.shape)
        for i, d in enumerate(spec):
            if d is None:
                continue
            names = (d,) if isinstance(d, str) else d
            size = int(np.prod([_axis_size(mesh, n) for n in names]))
            if leaf.shape[i] % size:
                bad.append((_path_str(path), leaf.shape, tuple(spec)))
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return bad


@pytest.mark.skipif(AbstractMesh is None, reason="needs AbstractMesh")
@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_and_cache_specs_divide(arch, multi_pod):
    cfg = get_config(arch)
    mesh = _mesh(multi_pod)
    sharder = Sharder(mesh, cfg)
    sharder.set_batch(128)
    model = Model(cfg)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params = jax.eval_shape(model.init, key)
    bad = _check_tree(sharder, params, sharder.param_spec, mesh)
    assert not bad, f"{arch}: non-divisible param shardings: {bad[:5]}"
    cache = jax.eval_shape(lambda: model.init_cache(128, 4096))
    bad = _check_tree(sharder, cache, sharder.cache_spec, mesh)
    assert not bad, f"{arch}: non-divisible cache shardings: {bad[:5]}"


@pytest.mark.skipif(AbstractMesh is None, reason="needs AbstractMesh")
def test_fsdp_shards_large_archs_over_data(caplog):
    cfg = get_config("qwen1_5_110b")
    sharder = Sharder(_mesh(), cfg)
    spec = sharder.param_spec("blocks/scan/0/mlp/wg", (80, 8192, 49152))
    assert "data" in jax.tree_util.tree_leaves(spec) or \
        any("data" in str(s) for s in spec)


@pytest.mark.skipif(AbstractMesh is None, reason="needs AbstractMesh")
def test_moe_ep_vs_tp_profiles():
    q = get_config("qwen3_moe_235b_a22b")   # 128 experts: EP
    m = get_config("mixtral_8x22b")          # 8 experts < 16: TP-in-expert
    sq = Sharder(_mesh(), q).param_spec("blocks/scan/0/moe/wg", (94, 128, 4096, 1536))
    sm = Sharder(_mesh(), m).param_spec("blocks/scan/0/moe/wg", (56, 8, 6144, 16384))
    assert sq[-3] == "model"        # experts sharded
    assert sm[-1] == "model"        # d_ff sharded inside experts
