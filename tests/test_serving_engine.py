"""Serving-engine tests: paged KV cache vs the contiguous oracle,
block-table accounting, prefill bucketing/compile counts, chunked
prefill, termination reasons, and the deterministic replay harness.
"""
import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.numerics import DotEngine
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.serving.engine import Request, ServeEngine
from repro.serving.replay import ReplayConfig, build_workload, run_replay

VOCAB = 512


def _tiny_cfg(**over):
    base = dict(name="t", family="dense", n_layers=2, d_model=16,
                n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=VOCAB,
                param_dtype="float32", compute_dtype="float32")
    base.update(over)
    return ModelConfig(**base)


def _tiny_model(mode="native", **eng_over):
    model = Model(_tiny_cfg(), DotEngine(mode=mode, **eng_over))
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, VOCAB, n).astype(np.int32) for n in lens]


def _serve(model, params, prompts, *, max_new=4, eos_id=None, **kw):
    eng = ServeEngine(model, params, **kw)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=max_new,
                           eos_id=eos_id))
    done = eng.run()
    return eng, sorted(done, key=lambda r: r.rid)


class TestTermination:
    def test_length_and_slot_recycling(self):
        model, params = _tiny_model()
        eng, done = _serve(model, params, _prompts([3, 5, 4, 6, 3, 7]),
                           max_new=4, slots=2, max_len=16,
                           kv_block_size=4, kv_blocks=9)
        assert len(done) == 6               # 6 requests through 2 slots
        assert all(r.finish_reason == "length" for r in done)
        assert all(len(r.output) == 4 for r in done)
        # every lane drained and returned its blocks
        assert not eng.active
        assert eng.free_blocks == eng.kv_blocks - 1
        assert all(eng.owned_blocks(s) == [] for s in range(eng.slots))

    def test_eos(self):
        model, params = _tiny_model()
        _, base = _serve(model, params, _prompts([5]), max_new=6,
                         slots=1, max_len=16)
        eos = base[0].output[1]             # greedy decode is deterministic
        _, done = _serve(model, params, _prompts([5]), max_new=6,
                         eos_id=eos, slots=1, max_len=16)
        assert done[0].finish_reason == "eos"
        assert done[0].output == base[0].output[:2]

    def test_max_len(self):
        model, params = _tiny_model()
        _, done = _serve(model, params, _prompts([12]), max_new=20,
                         slots=1, max_len=16)
        assert done[0].finish_reason == "max_len"
        # positions 12..15 get written: 4 new tokens fit before the wall
        assert len(done[0].output) == 4

    def test_cache_full_admission_deadlock(self):
        model, params = _tiny_model()
        # 9-token prompt needs 3 blocks; pool has 2 usable and nothing
        # running to wait for -> immediate cache_full, never activated
        _, done = _serve(model, params, _prompts([9]), max_new=4,
                         slots=1, max_len=16, kv_block_size=4, kv_blocks=3)
        assert done[0].finish_reason == "cache_full"
        assert done[0].output == []
        assert done[0].s_done is not None

    def test_cache_full_mid_decode(self):
        model, params = _tiny_model()
        # prompt fills both usable blocks; the first decode write needs a
        # third -> terminate with what we have
        _, done = _serve(model, params, _prompts([4]), max_new=6,
                         slots=1, max_len=16, kv_block_size=2, kv_blocks=3)
        assert done[0].finish_reason == "cache_full"
        assert len(done[0].output) == 1     # prefill token only

    def test_prompt_length_validated(self):
        model, params = _tiny_model()
        eng = ServeEngine(model, params, slots=1, max_len=8)
        with pytest.raises(ValueError, match="prompt length"):
            eng.submit(Request(rid=0, prompt=np.zeros(8, np.int32)))
        with pytest.raises(ValueError, match="prompt length"):
            eng.submit(Request(rid=1, prompt=np.zeros(0, np.int32)))


class TestBlockAccounting:
    def test_lifo_reuse(self):
        model, params = _tiny_model()
        eng = ServeEngine(model, params, slots=1, max_len=16,
                          kv_block_size=4)
        done = []
        # 7-token prompt: 2 blocks, and the first decode write (pos 7)
        # still lands in block 1 — owned stays [1, 2] across the step
        eng.submit(Request(rid=0, prompt=_prompts([7])[0],
                           max_new_tokens=4))
        eng.step(done)
        first = eng.owned_blocks(0)
        assert first == [1, 2]              # free list pops low ids first
        eng.run()
        assert eng.owned_blocks(0) == []
        eng.submit(Request(rid=1, prompt=_prompts([7], seed=1)[0],
                           max_new_tokens=4))
        eng.step(done)
        assert eng.owned_blocks(0) == first  # freed blocks reused LIFO
        eng.run()

    def test_peak_usage_tracked_within_pool(self):
        model, params = _tiny_model()
        eng, _ = _serve(model, params, _prompts([6, 7, 5, 6]), max_new=4,
                        slots=2, max_len=16, kv_block_size=4)
        usable = eng.kv_blocks - 1
        assert 0 < eng.blocks_peak_used <= usable
        assert eng.kv_report()["kv_blocks_peak_used"] == eng.blocks_peak_used

    def test_kv_report_resident_below_contiguous(self):
        model, params = _tiny_model()
        eng, _ = _serve(model, params, _prompts([5, 6]), max_new=3,
                        slots=4, max_len=64, kv_block_size=8, kv_blocks=9)
        rep = eng.kv_report()
        assert rep["kv_layout"] == "paged"
        assert 0 < rep["kv_bytes_resident"] < rep["kv_bytes_contiguous"]
        assert rep["kv_blocks_free"] == rep["kv_blocks_usable"] == 8
        ceng, _ = _serve(model, params, _prompts([5, 6]), max_new=3,
                         slots=4, max_len=64, kv_layout="contiguous")
        crep = ceng.kv_report()
        assert crep["kv_bytes_resident"] == crep["kv_bytes_contiguous"]
        assert crep["kv_bytes_contiguous"] == rep["kv_bytes_contiguous"]


class TestPagedIdentity:
    @pytest.mark.parametrize("mode", sorted(DotEngine.modes()))
    def test_paged_matches_contiguous_every_dot_mode(self, mode):
        # Modes whose WORKING precision exceeds 16 digits need the wide
        # decode, and their broadcast oracle refuses inside an outer jit
        # without ambient x64; the Pallas interpret path never needs
        # x64, so those modes take it — same dispatch a real deployment
        # uses. Truncated tiers run at p work digits (olm32t16 drops
        # back inside the plain-f32 window).
        m = re.fullmatch(r"olm(\d+)(?:t(\d+))?", mode)
        use_pallas = bool(m) and int(m.group(2) or m.group(1)) > 16
        model, params = _tiny_model(mode, use_pallas=use_pallas)
        prompts = _prompts([3, 6, 5])
        kw = dict(max_new=4, slots=2, max_len=16)
        _, paged = _serve(model, params, prompts, kv_layout="paged",
                          kv_block_size=4, kv_blocks=9, **kw)
        _, contig = _serve(model, params, prompts, kv_layout="contiguous",
                           **kw)
        for p, c in zip(paged, contig):
            assert p.output == c.output, mode

    def test_engine_matches_offline_decode_paged(self):
        model, params = _tiny_model()
        prompt = _prompts([5])[0]
        _, done = _serve(model, params, [prompt], max_new=4, slots=2,
                         max_len=32, kv_block_size=8)
        cache = model.init_cache(1, 32)
        lg, cache, mem = model.prefill(
            params, {"tokens": jnp.asarray(prompt[None])}, cache)
        toks = [int(jnp.argmax(lg[0]))]
        pos = len(prompt)
        for _ in range(3):
            lg, cache = model.decode_step(
                params, jnp.asarray([toks[-1]]), jnp.asarray([pos]),
                cache, mem)
            toks.append(int(jnp.argmax(lg[0])))
            pos += 1
        assert done[0].output == toks


class TestPrefillBuckets:
    def test_compile_count_stays_at_bucket_count(self):
        model, params = _tiny_model()
        eng = ServeEngine(model, params, slots=4, max_len=32,
                          prefill_bucket_min=8)
        assert eng._bucketed
        done = []
        # 4 distinct prompt lengths, one shared (4, 8) bucket -> 1 trace
        for rid, p in enumerate(_prompts([3, 4, 5, 6])):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=3))
        eng.run()
        assert eng.prefill_traces == 1
        assert eng.decode_traces == 1
        # new lengths, same buckets -> no new compiles
        for rid, p in enumerate(_prompts([7, 8, 6, 5], seed=1), start=4):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=3))
        eng.run()
        assert eng.prefill_traces == 1
        # longer prompts cross into the (1, 16) bucket -> exactly 1 more
        eng.submit(Request(rid=8, prompt=_prompts([12])[0],
                           max_new_tokens=3))
        eng.run()
        assert eng.prefill_traces == 2
        assert eng.decode_traces == 1       # decode shape never changes

    def test_bucketing_disabled_for_sliding_window(self):
        model = Model(_tiny_cfg(sliding_window=8), DotEngine())
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, slots=2, max_len=16,
                          kv_layout="contiguous")
        assert not eng._bucketed
        with pytest.raises(ValueError, match="sliding_window|attention-only"):
            ServeEngine(model, params, slots=2, max_len=16,
                        kv_layout="contiguous", prefill_chunk=4)
        # exact-length prefill still serves correctly
        for rid, p in enumerate(_prompts([4, 6])):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=3))
        done = eng.run()
        assert sorted(r.rid for r in done) == [0, 1]
        assert all(len(r.output) == 3 for r in done)


class TestChunkedPrefill:
    def test_chunked_identical_to_unchunked(self):
        model, params = _tiny_model()
        prompts = _prompts([10, 3, 7])
        kw = dict(max_new=4, slots=2, max_len=16, kv_block_size=4,
                  kv_blocks=11)
        _, plain = _serve(model, params, prompts, **kw)
        _, chunked = _serve(model, params, prompts, prefill_chunk=4, **kw)
        for p, c in zip(plain, chunked):
            assert p.output == c.output

    def test_chunk_must_divide_max_len(self):
        model, params = _tiny_model()
        with pytest.raises(ValueError, match="divide max_len"):
            ServeEngine(model, params, slots=1, max_len=16,
                        prefill_chunk=5)


class TestReplay:
    def test_workload_deterministic(self):
        cfg = ReplayConfig(seed=3, n_requests=6, vocab=VOCAB)
        a, b = build_workload(cfg), build_workload(cfg)
        assert [w["arrival_step"] for w in a] == \
            [w["arrival_step"] for w in b]
        for wa, wb in zip(a, b):
            np.testing.assert_array_equal(wa["prompt"], wb["prompt"])
            assert wa["max_new"] == wb["max_new"]

    def test_replay_step_metrics_stable_across_runs(self):
        model, params = _tiny_model()
        cfg = ReplayConfig(seed=0, n_requests=6, prompt_len_range=(2, 6),
                           max_new_range=(2, 4), vocab=VOCAB)
        wl = build_workload(cfg)

        def go():
            eng = ServeEngine(model, params, slots=2, max_len=16,
                              kv_block_size=4, kv_blocks=9)
            done, rep = run_replay(eng, wl)
            rep.pop("wall_s")
            return rep, {r.rid: r.output for r in done}

        rep_a, out_a = go()
        rep_b, out_b = go()
        assert rep_a == rep_b
        assert out_a == out_b
        assert rep_a["n"] == 6
        assert rep_a["ttft_steps_p99"] >= rep_a["ttft_steps_p50"] >= 0
        assert rep_a["e2e_steps_p99"] >= rep_a["e2e_steps_p50"] >= 0


class TestLatencyReport:
    def test_fields_present_and_ordered(self):
        model, params = _tiny_model()
        _, done = _serve(model, params, _prompts([3, 5, 4]), max_new=3,
                         slots=2, max_len=16, kv_block_size=4)
        rep = ServeEngine.latency_report(done)
        for k in ("n", "ttft_mean_s", "ttft_p50_s", "ttft_p99_s",
                  "e2e_mean_s", "e2e_p50_s", "e2e_p99_s",
                  "queue_wait_mean_s", "new_tokens", "tokens_per_s"):
            assert k in rep, k
        assert rep["n"] == 3
        assert rep["new_tokens"] == 9
        assert rep["ttft_p99_s"] >= rep["ttft_p50_s"] >= 0
        assert rep["e2e_p99_s"] >= rep["e2e_p50_s"] >= 0
        assert rep["tokens_per_s"] > 0
        assert ServeEngine.latency_report([]) == {}
