"""Fault-tolerance tests for the serving engine (Issue 9): deadlines,
admission backpressure, preemption-with-recompute, the tier-degradation
ladder, allocator integrity guards, the NaN/Inf numerics guard, and the
finish_reason lattice across layouts and quality tiers.

Token-identity assertions lean on the paged slot == position invariant:
a preempted lane re-prefilled from prompt + accumulated output must
resume bit-identically, so every recovery path is checked against an
unconstrained reference run of the same requests.
"""
import jax
import numpy as np
import pytest

from repro.core.numerics import DotEngine
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.serving.degrade import DegradeLadder
from repro.serving.engine import Request, ServeEngine

VOCAB = 512


def _tiny_cfg(**over):
    base = dict(name="t", family="dense", n_layers=2, d_model=16,
                n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=VOCAB,
                param_dtype="float32", compute_dtype="float32")
    base.update(over)
    return ModelConfig(**base)


def _tiny_model(mode="native", **eng_over):
    model = Model(_tiny_cfg(), DotEngine(mode=mode, **eng_over))
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, VOCAB, n).astype(np.int32) for n in lens]


def _serve(model, params, prompts, *, max_new=4, eos_id=None,
           reqs=None, **kw):
    eng = ServeEngine(model, params, **kw)
    if reqs is None:
        reqs = [Request(rid=rid, prompt=p, max_new_tokens=max_new,
                        eos_id=eos_id) for rid, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    return eng, sorted(done, key=lambda r: r.rid)


@pytest.fixture(scope="module")
def tiny():
    return _tiny_model()


class TestDeadlines:
    @pytest.mark.parametrize("layout", ["paged", "contiguous"])
    def test_expires_while_queued(self, tiny, layout):
        model, params = tiny
        # slots=1: rid 1 waits behind an 8-token decode and its 2-step
        # budget expires in the queue — finished at the schedule
        # boundary, never activated
        reqs = [Request(rid=0, prompt=_prompts([4])[0], max_new_tokens=8),
                Request(rid=1, prompt=_prompts([4], seed=1)[0],
                        max_new_tokens=8, deadline_steps=2)]
        eng, done = _serve(model, params, None, reqs=reqs, slots=1,
                           max_len=16, kv_layout=layout, kv_block_size=4)
        assert done[0].finish_reason == "length"
        assert done[1].finish_reason == "deadline"
        assert done[1].output == []
        assert done[1].s_done == 2
        rep = ServeEngine.latency_report(done)
        assert rep["finish_reasons"] == {"length": 1, "deadline": 1}
        assert eng.counters["deadline"] == 1

    @pytest.mark.parametrize("layout", ["paged", "contiguous"])
    def test_expires_mid_decode_keeps_clean_prefix(self, tiny, layout):
        model, params = tiny
        kw = dict(slots=1, max_len=32, kv_layout=layout, kv_block_size=4)
        _, base = _serve(model, params, _prompts([5]), max_new=10, **kw)
        req = Request(rid=0, prompt=_prompts([5])[0], max_new_tokens=10,
                      deadline_steps=4)
        _, done = _serve(model, params, None, reqs=[req], **kw)
        assert done[0].finish_reason == "deadline"
        # never cut mid-token: the partial stream is a prefix of the
        # uninterrupted run
        n = len(done[0].output)
        assert 0 < n < 10
        assert done[0].output == base[0].output[:n]

    def test_deadline_validated(self, tiny):
        model, params = tiny
        eng = ServeEngine(model, params, slots=1, max_len=16)
        with pytest.raises(ValueError, match="deadline_steps"):
            eng.submit(Request(rid=0, prompt=_prompts([4])[0],
                               deadline_steps=0))


class TestBackpressure:
    def test_overflow_sheds_rejected(self, tiny):
        model, params = tiny
        eng = ServeEngine(model, params, slots=1, max_len=16,
                          max_queue=2, kv_block_size=4)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=3)
                for i, p in enumerate(_prompts([4, 4, 4, 4]))]
        admitted = [eng.submit(r) for r in reqs]
        assert admitted == [True, True, False, False]
        done = sorted(eng.run(), key=lambda r: r.rid)
        assert len(done) == 4               # sheds drain into done
        assert [r.finish_reason for r in done] == \
            ["length", "length", "rejected", "rejected"]
        assert all(r.output == [] and r.s_done is not None
                   for r in done[2:])
        rep = ServeEngine.latency_report(done)
        assert rep["finish_reasons"] == {"length": 2, "rejected": 2}
        assert eng.counters["rejected"] == 2

    def test_max_queue_validated(self, tiny):
        model, params = tiny
        with pytest.raises(ValueError, match="max_queue"):
            ServeEngine(model, params, slots=1, max_len=16, max_queue=0)


class TestPreemption:
    # Pool sized so two 8-token decodes genuinely collide: 5 usable
    # blocks, each lane peaks at 3 — the second grower gets evicted and
    # must recompute.
    KW = dict(slots=2, max_len=16, kv_block_size=4, kv_blocks=6)
    BIG = dict(slots=2, max_len=16, kv_block_size=4, kv_blocks=16)

    def test_recompute_is_bit_identical(self, tiny):
        model, params = tiny
        prompts = _prompts([4, 4])
        _, big = _serve(model, params, prompts, max_new=8, **self.BIG)
        eng, done = _serve(model, params, prompts, max_new=8, **self.KW)
        assert eng.counters["preempted"] >= 1
        assert sum(r.n_preempts for r in done) >= 1
        for r, b in zip(done, big):
            assert r.finish_reason == "length"
            assert r.output == b.output     # recompute invariant
        assert eng.free_blocks == eng.kv_blocks - 1
        assert eng.kv_report()["integrity_ok"]

    def test_victim_is_lowest_priority(self, tiny):
        model, params = tiny
        prompts = _prompts([4, 4])
        # rid 0 has the LOWER priority: it gets evicted even though the
        # tie-break (highest rid) would otherwise pick rid 1
        reqs = [Request(rid=0, prompt=prompts[0], max_new_tokens=8,
                        priority=0),
                Request(rid=1, prompt=prompts[1], max_new_tokens=8,
                        priority=1)]
        _, big = _serve(model, params, prompts, max_new=8, **self.BIG)
        _, done = _serve(model, params, None, reqs=reqs, **self.KW)
        assert done[0].n_preempts >= 1
        assert done[1].n_preempts == 0
        for r, b in zip(done, big):
            assert r.output == b.output

    def test_preempt_false_restores_terminal_cache_full(self, tiny):
        model, params = tiny
        _, done = _serve(model, params, _prompts([4]), max_new=6,
                         slots=1, max_len=16, kv_block_size=2,
                         kv_blocks=3, preempt=False)
        assert done[0].finish_reason == "cache_full"
        assert len(done[0].output) == 1
        assert done[0].n_preempts == 0

    def test_preempt_limit_bounds_pingpong(self, tiny):
        model, params = tiny
        eng = ServeEngine(model, params, slots=1, max_len=16,
                          kv_block_size=4, preempt_limit=1)
        eng.submit(Request(rid=0, prompt=_prompts([3])[0],
                           max_new_tokens=8))
        done = []
        eng.step(done)
        req = eng.active[0]
        eng._preempt(0, req, done)          # 1st: requeue + recompute
        assert req.n_preempts == 1 and not done
        eng.step(done)                      # re-prefill
        eng._preempt(0, eng.active[0], done)  # 2nd: past the limit
        assert done and done[0].finish_reason == "cache_full"
        assert eng.counters["preempted"] == 1
        assert eng.counters["cache_full"] == 1


class TestAdmissionDeadlockGuard:
    def test_transient_hold_waits_instead_of_terminal(self, tiny):
        model, params = tiny
        # prompt needs 2 of 3 usable blocks — servable, but all three
        # are reserved out of the pool: the request must WAIT (the old
        # guard would have killed it as an idle-engine deadlock)
        eng = ServeEngine(model, params, slots=1, max_len=16,
                          kv_block_size=4, kv_blocks=4)
        held = eng.reserve_blocks(3)
        assert eng.free_blocks == 0
        assert eng.kv_report()["kv_blocks_held"] == 3
        eng.submit(Request(rid=0, prompt=_prompts([8])[0],
                           max_new_tokens=3))
        done = []
        for _ in range(4):
            eng.step(done)
        assert not done and len(eng.queue) == 1
        eng.release_blocks(held)
        done = eng.run()
        assert done[0].finish_reason == "length"
        assert eng.kv_report()["integrity_ok"]

    def test_unservable_prompt_still_terminal(self, tiny):
        model, params = tiny
        # 9 tokens need 3 blocks; the whole pool holds 2 — can never be
        # served, terminal cache_full (pre-existing semantics)
        _, done = _serve(model, params, _prompts([9]), max_new=4,
                         slots=1, max_len=16, kv_block_size=4,
                         kv_blocks=3)
        assert done[0].finish_reason == "cache_full"
        assert done[0].output == []

    def test_reserve_requires_paged(self, tiny):
        model, params = tiny
        eng = ServeEngine(model, params, slots=1, max_len=16,
                          kv_layout="contiguous")
        with pytest.raises(ValueError, match="paged"):
            eng.reserve_blocks(1)


class TestDegradeLadder:
    def test_build_validation(self):
        with pytest.raises(ValueError, match=">= 2 rungs"):
            DegradeLadder.build(["native"], base_mode="native")
        with pytest.raises(ValueError, match="not registered"):
            DegradeLadder.build(["native", "olm7"], base_mode="native")
        with pytest.raises(ValueError, match="rung 0"):
            DegradeLadder.build(["olm8", "olm16"], base_mode="native")
        with pytest.raises(ValueError, match="duplicate"):
            DegradeLadder.build(["native", "olm8", "olm8"],
                                base_mode="native")
        lad = DegradeLadder.build(["native", "olm8"], base_mode="native")
        assert lad.rung_of("native") == 0
        assert lad.rung_of(None) == 0       # unladdered tiers start at 0
        assert lad.next_mode(0) == "olm8"
        assert lad.next_mode(1) is None
        assert lad.kv_pressure(1, 8)        # 1/8 < 0.25
        assert not lad.kv_pressure(4, 8)
        assert not lad.kv_pressure(0, 0)    # contiguous: no pool

    def test_overflow_downshift_matches_dedicated_deployment(self, tiny):
        model, params = tiny
        prompts = _prompts([4, 5, 6])
        eng = ServeEngine(model, params, slots=1, max_len=16,
                          kv_block_size=4, max_queue=1,
                          degrade_ladder=["native", "olm8"],
                          degrade_queue_headroom=1)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        assert eng.submit(reqs[0])          # fills the queue
        assert eng.submit(reqs[1])          # re-admitted one rung down
        assert not eng.submit(reqs[2])      # headroom spent: rejected
        done = sorted(eng.run(), key=lambda r: r.rid)
        assert done[0].finish_reason == "length"
        assert done[0].served_tier == "native" and done[0].degrade_rung == 0
        assert done[1].finish_reason == "length"
        assert done[1].served_tier == "olm8" and done[1].degrade_rung == 1
        assert done[2].finish_reason == "rejected"
        assert eng.counters["degraded"] == 1
        # the degraded request is served exactly as a dedicated olm8
        # deployment would serve it
        model8, params8 = _tiny_model("olm8")
        _, ded = _serve(model8, params8, [prompts[1]], max_new=4,
                        slots=1, max_len=16, kv_block_size=4)
        assert done[1].output == ded[0].output

    def test_preempt_downshift_under_kv_pressure(self, tiny):
        model, params = tiny
        eng = ServeEngine(model, params, slots=1, max_len=16,
                          kv_block_size=4, kv_blocks=9,
                          degrade_ladder=["native", "olm8"],
                          # the evicted lane's own 2 blocks come back
                          # before the pressure check: 2/8 free must
                          # still count as pressure here
                          degrade_free_frac=0.5)
        eng.submit(Request(rid=0, prompt=_prompts([4])[0],
                           max_new_tokens=6))
        done = []
        eng.step(done)
        held = eng.reserve_blocks(eng.free_blocks)  # free/usable -> low
        eng._preempt(0, eng.active[0], done)
        eng.release_blocks(held)
        done += eng.run()
        assert done[0].finish_reason == "length"
        assert done[0].n_preempts == 1
        assert done[0].degrade_rung == 1
        assert done[0].served_tier == "olm8"

    def test_ladder_rung_collision_with_quality_tier(self, tiny):
        model, params = tiny
        with pytest.raises(ValueError, match="collides"):
            ServeEngine(model, params, slots=1, max_len=16,
                        quality_tiers={"olm8": "olm16"},
                        degrade_ladder=["native", "olm8"])


class TestIntegrityGuards:
    def test_double_free_raises(self, tiny):
        model, params = tiny
        eng = ServeEngine(model, params, slots=1, max_len=16,
                          kv_block_size=4)
        eng.submit(Request(rid=0, prompt=_prompts([4])[0],
                           max_new_tokens=8))
        eng.step([])
        owned = eng.owned_blocks(0)
        assert owned
        eng._free_slot_blocks(0)
        eng._owned[0] = owned               # simulate corrupted shadow
        with pytest.raises(RuntimeError, match="double-free"):
            eng._free_slot_blocks(0)

    def test_corrupted_free_list_detected_at_alloc(self, tiny):
        model, params = tiny
        eng = ServeEngine(model, params, slots=2, max_len=16,
                          kv_block_size=4)
        eng.submit(Request(rid=0, prompt=_prompts([4])[0],
                           max_new_tokens=8))
        eng.step([])
        owned_bid = eng.owned_blocks(0)[0]
        eng._free.append(owned_bid)         # duplicate of a live block
        with pytest.raises(RuntimeError, match="free list corrupted"):
            eng._alloc_blocks(1, 1)

    def test_audit_repairs_active_lane_by_recompute(self, tiny):
        model, params = tiny
        kw = dict(slots=1, max_len=16, kv_block_size=4)
        _, base = _serve(model, params, _prompts([4]), max_new=8, **kw)
        eng = ServeEngine(model, params, integrity_audit=True, **kw)
        eng.submit(Request(rid=0, prompt=_prompts([4])[0],
                           max_new_tokens=8))
        done = []
        eng.step(done)
        eng.corrupt_table_entry(0, 0, eng.kv_blocks + 3)
        assert not eng.kv_report()["integrity_ok"]
        done += eng.run()
        assert eng.counters["table_repairs"] == 1
        assert done[0].n_preempts == 1
        assert done[0].finish_reason == "length"
        assert done[0].output == base[0].output  # recovered bit-identical
        assert eng.kv_report()["integrity_ok"]

    def test_audit_rebuilds_idle_lane_row(self, tiny):
        model, params = tiny
        eng = ServeEngine(model, params, slots=2, max_len=16,
                          kv_block_size=4, integrity_audit=True)
        eng.submit(Request(rid=0, prompt=_prompts([4])[0],
                           max_new_tokens=4))
        done = []
        eng.step(done)
        eng.corrupt_table_entry(1, 0, eng.kv_blocks + 3)  # idle lane
        done += eng.run()
        assert eng.counters["table_repairs"] == 1
        assert done[0].n_preempts == 0      # active lane untouched
        assert eng.kv_report()["integrity_ok"]


class TestNumericsGuard:
    def test_decode_nan_finishes_with_clean_prefix(self, tiny):
        model, params = tiny
        kw = dict(slots=1, max_len=32, kv_block_size=4)
        _, base = _serve(model, params, _prompts([5]), max_new=8, **kw)
        eng = ServeEngine(model, params, numerics_check=True, **kw)
        calls = []

        def tap(lg, phase, step):
            if phase == "decode":
                calls.append(step)
                if len(calls) == 3:
                    lg = lg.copy()
                    lg[min(eng.active), :] = np.nan
            return lg

        eng.logits_tap = tap
        eng.submit(Request(rid=0, prompt=_prompts([5])[0],
                           max_new_tokens=8))
        done = eng.run()
        assert done[0].finish_reason == "numerics"
        # the poisoned token is never appended: 1 prefill + 2 clean
        # decode tokens, a prefix of the healthy stream
        assert done[0].output == base[0].output[:3]
        assert eng.counters["numerics"] == 1
        assert eng.free_blocks == eng.kv_blocks - 1

    def test_prefill_nan_never_activates(self, tiny):
        model, params = tiny
        eng = ServeEngine(model, params, slots=2, max_len=16,
                          kv_block_size=4, numerics_check=True)

        def tap(lg, phase, step):
            if phase == "prefill":
                lg = lg.copy()
                lg[0, :] = np.inf
            return lg

        eng.logits_tap = tap
        eng.submit(Request(rid=0, prompt=_prompts([4])[0],
                           max_new_tokens=4))
        done = eng.run()
        assert done[0].finish_reason == "numerics"
        assert done[0].output == []
        assert done[0].t_first is None
        assert eng.free_blocks == eng.kv_blocks - 1
        assert eng.kv_report()["integrity_ok"]

    def test_off_by_default_streams_through(self, tiny):
        model, params = tiny
        eng = ServeEngine(model, params, slots=1, max_len=16)
        assert eng.numerics_check is False and eng.logits_tap is None


class TestFinishReasonLattice:
    """One run producing eos/length/max_len/deadline/rejected together,
    across both KV layouts and across quality tiers; cache_full,
    numerics, and failed have dedicated tests above/in
    test_serving_faults.py. latency_report must count every reason."""

    @pytest.mark.parametrize("layout", ["paged", "contiguous"])
    @pytest.mark.parametrize("tier", [None, "fast"])
    def test_all_reasons_counted(self, tiny, layout, tier):
        model, params = tiny
        tiers = {"fast": "olm8"} if tier else None
        kw = dict(slots=1, max_len=16, kv_layout=layout, kv_block_size=4,
                  quality_tiers=tiers)
        prompts = _prompts([4, 5, 12, 4, 4, 4])
        # eos token must come from the tier actually serving the request
        _, probe = _serve(model, params, None, reqs=[
            Request(rid=0, prompt=prompts[1], max_new_tokens=6,
                    quality_tier=tier)], **kw)
        eos = probe[0].output[1]
        reqs = [
            Request(rid=0, prompt=prompts[0], max_new_tokens=3,
                    quality_tier=tier),                       # length
            Request(rid=1, prompt=prompts[1], max_new_tokens=6,
                    eos_id=eos, quality_tier=tier),           # eos
            Request(rid=2, prompt=prompts[2], max_new_tokens=20,
                    quality_tier=tier),                       # max_len
            Request(rid=3, prompt=prompts[3], max_new_tokens=3,
                    deadline_steps=2, quality_tier=tier),     # deadline
            Request(rid=4, prompt=prompts[4], max_new_tokens=3,
                    quality_tier=tier),                       # rejected
            Request(rid=5, prompt=prompts[5], max_new_tokens=3,
                    quality_tier=tier),                       # rejected
        ]
        eng, done = _serve(model, params, None, reqs=reqs,
                           max_queue=4, **kw)
        assert len(done) == 6
        by_rid = {r.rid: r.finish_reason for r in done}
        assert by_rid == {0: "length", 1: "eos", 2: "max_len",
                          3: "deadline", 4: "rejected", 5: "rejected"}
        rep = ServeEngine.latency_report(done)
        assert rep["finish_reasons"] == {
            "length": 1, "eos": 1, "max_len": 1, "deadline": 1,
            "rejected": 2}
        assert sum(rep["finish_reasons"].values()) == rep["n"]
        assert dict(eng.counters) == rep["finish_reasons"]
        want_mode = "olm8" if tier else "native"
        served = [r for r in done if r.output]
        assert served and all(r.served_tier == want_mode for r in served)
