"""tpmm Pallas kernel vs jnp oracle vs exact matmul, shape/dtype sweeps."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.tpmm.ops import tpmm, tpmm_cost_model
from repro.kernels.tpmm.quantize import plane_decompose, plane_reconstruct
from repro.kernels.tpmm.ref import kept_levels, num_planes_for

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


class TestQuantize:
    @pytest.mark.parametrize("plane_bits", [2, 4, 6])
    @pytest.mark.parametrize("dtype", [np.float32, np.float16])
    def test_roundtrip(self, rng, plane_bits, dtype):
        a = rng.standard_normal((32, 48)).astype(dtype)
        D = num_planes_for(16, plane_bits)
        p, s = plane_decompose(jnp.asarray(a), num_planes=D, plane_bits=plane_bits)
        rec = np.asarray(plane_reconstruct(p, s, plane_bits=plane_bits))
        ulp = np.asarray(s).max() * 2.0 ** -(plane_bits * D)
        assert np.max(np.abs(rec - a.astype(np.float32))) <= 0.51 * ulp + 1e-7

    def test_planes_in_balanced_range(self, rng):
        a = rng.standard_normal((16, 16)).astype(np.float32) * 100
        p, _ = plane_decompose(jnp.asarray(a), num_planes=4, plane_bits=4)
        assert np.asarray(p).min() >= -8 and np.asarray(p).max() <= 8

    def test_digit_extraction_exhaustive(self):
        B, D = 16, 2
        for v in range(-(B**D) // 2, B**D // 2 + 1):
            vv, digs = v, []
            for _ in range(D):
                q = int(np.sign(vv)) * ((abs(vv) + B // 2 - 1) // B)
                digs.append(vv - B * q)
                vv = q
            assert vv == 0 and all(abs(d) <= B // 2 for d in digs)
            assert sum(d * B**i for i, d in enumerate(digs)) == v


class TestKernelVsRef:
    @pytest.mark.parametrize("shape", [(128, 128, 128), (64, 256, 64),
                                       (100, 130, 60), (8, 8, 8)])
    @pytest.mark.parametrize("n_bits", [8, 16])
    def test_bitwise_match(self, rng, shape, n_bits):
        M, K, N = shape
        a = rng.standard_normal((M, K)).astype(np.float32)
        b = rng.standard_normal((K, N)).astype(np.float32)
        gk = np.asarray(tpmm(jnp.asarray(a), jnp.asarray(b), n_bits=n_bits,
                             block_m=32, block_n=32, block_k=32))
        gr = np.asarray(tpmm(jnp.asarray(a), jnp.asarray(b), n_bits=n_bits,
                             use_pallas=False))
        np.testing.assert_allclose(gk, gr, atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("dtype", [np.float32, np.float16])
    def test_dtype_sweep(self, rng, dtype):
        a = rng.standard_normal((64, 64)).astype(dtype)
        b = rng.standard_normal((64, 64)).astype(dtype)
        gk = np.asarray(tpmm(jnp.asarray(a), jnp.asarray(b), n_bits=16,
                             block_m=32, block_n=32, block_k=32))
        gr = np.asarray(tpmm(jnp.asarray(a), jnp.asarray(b), n_bits=16,
                             use_pallas=False))
        np.testing.assert_allclose(gk, gr, atol=1e-5, rtol=1e-5)


class TestAccuracy:
    @pytest.mark.parametrize("n_bits,rel_tol", [(8, 0.08), (16, 6e-4), (24, 6e-6)])
    def test_truncated_error_bound(self, rng, n_bits, rel_tol):
        M = K = N = 128
        a = rng.standard_normal((M, K)).astype(np.float32)
        b = rng.standard_normal((K, N)).astype(np.float32)
        exact = a @ b
        got = np.asarray(tpmm(jnp.asarray(a), jnp.asarray(b), n_bits=n_bits,
                              use_pallas=False))
        rel = np.max(np.abs(got - exact)) / np.abs(exact).max()
        assert rel < rel_tol

    def test_modes_ordering(self, rng):
        a = rng.standard_normal((64, 64)).astype(np.float32)
        b = rng.standard_normal((64, 64)).astype(np.float32)
        exact = a @ b
        errs = {}
        for mode in ("full", "nbit", "eq8"):
            got = np.asarray(tpmm(jnp.asarray(a), jnp.asarray(b), n_bits=16,
                                  use_pallas=False, mode=mode))
            errs[mode] = np.max(np.abs(got - exact))
        assert errs["full"] <= errs["nbit"] <= errs["eq8"]


class TestCostModel:
    def test_savings_trend(self):
        # MXU-op savings grow with precision like the paper's area savings
        s = [tpmm_cost_model(n)["mxu_savings_pct"] for n in (8, 16, 24, 32)]
        assert s == sorted(s)
        assert 20 < s[0] < 30 and 40 < s[-1] < 50

    def test_levels(self):
        assert kept_levels(16, 4, mode="full") == 7
        assert kept_levels(16, 4, mode="nbit") == 4
        assert kept_levels(16, 4, mode="eq8") == 3


if HAVE_HYP:

    @given(
        m=st.integers(1, 5), k=st.integers(1, 6), n=st.integers(1, 5),
        n_bits=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=20, deadline=None)
    def test_property_kernel_matches_ref(m, k, n, n_bits, seed):
        r = np.random.default_rng(seed)
        M, K, N = 8 * m, 8 * k, 8 * n
        a = r.standard_normal((M, K)).astype(np.float32)
        b = r.standard_normal((K, N)).astype(np.float32)
        gk = np.asarray(tpmm(jnp.asarray(a), jnp.asarray(b), n_bits=n_bits,
                             block_m=8, block_n=8, block_k=8))
        gr = np.asarray(tpmm(jnp.asarray(a), jnp.asarray(b), n_bits=n_bits,
                             use_pallas=False))
        np.testing.assert_allclose(gk, gr, atol=1e-5, rtol=1e-5)
