"""olmlint analyzer tests (Issue 6): every contract fails on a fixture
violation with its named contract id, and the shipped kernels pass
clean at all four registered widths under both x64 settings."""
import functools
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import ast_lint, overflow, run_ast_lint, vmem
from repro.analysis.jaxpr_lint import check_case, check_jaxpr
from repro.analysis.registry import KernelCase, iter_cases
from repro.configs.olm_array import MATMUL_MODES
from repro.core.precision import OnlinePrecision
from repro.kernels.online_dot import tuning

WIDTHS = tuple(sorted(MATMUL_MODES))


def _case(name, fn, shape=(4,), dtype=jnp.int32, out_dtypes=("int32",)):
    return KernelCase(
        name=name, n_bits=8,
        trace=functools.partial(jax.make_jaxpr(fn),
                                jax.ShapeDtypeStruct(shape, dtype)),
        out_dtypes=out_dtypes, tiling=None)


def _contracts(violations):
    return {v.contract for v in violations}


# ---------------------------------------------------------------- fixtures


def test_fixture_int64_eqn_fails_named_contract():
    case = _case("fixture-int64",
                 lambda x: (x.astype(jnp.int64) + 1).astype(jnp.int32))
    assert "kernel-no-int64" in _contracts(check_case(case))


def test_fixture_transcendental_fails_named_contract():
    case = _case("fixture-exp2", jnp.exp2, dtype=jnp.float32,
                 out_dtypes=("float32",))
    assert "kernel-no-transcendental" in _contracts(check_case(case))


def test_fixture_1d_iota_fails_named_contract():
    case = _case("fixture-iota",
                 lambda x: x + jax.lax.iota(jnp.int32, 4))
    assert "kernel-no-1d-iota" in _contracts(check_case(case))


def test_fixture_accum_dtype_mismatch_fails_named_contract():
    # body genuinely returns float32; the case declares int32
    case = _case("fixture-accum", lambda x: x.astype(jnp.float32),
                 out_dtypes=("int32",))
    assert "kernel-accum-dtype" in _contracts(check_case(case))


def test_fixture_weak_literal_int64_fails_named_contract():
    # the exact leak class the kernels were scrubbed of: a bare Python
    # int in a where branch traces as a weak int64 aval under x64
    case = _case("fixture-weak-literal",
                 lambda x: jnp.where(x > 0, 1, jnp.where(x < 0, -1, 0))
                 .astype(jnp.int32))
    assert "kernel-no-int64" in _contracts(check_case(case))


def test_fixture_overflowing_schedule_fails_named_contract():
    # untruncated n=32: S = 35, first live register write is 2^34
    cfg = OnlinePrecision(n=32, truncated=False)
    vs = overflow.check_schedule(cfg, where="fixture")
    assert _contracts(vs) == {"int32-overflow"}
    bits, _ = overflow.prove_schedule(cfg)
    assert bits > 31


def test_fixture_over_budget_tiling_fails_named_contract():
    # 8*8*256 = 16384 lanes >> lane_budget(32) = 1024
    vs = vmem.check_matmul_tiling(32, 256, 8, 8, where="fixture")
    assert "vmem-budget" in _contracts(vs)


def test_fixture_oversized_k_tile_fails_decode_window():
    kt = 2 * tuning.max_k_tile(16)
    vs = vmem.check_matmul_tiling(16, kt, 1, 1, where="fixture")
    assert "decode-window" in _contracts(vs)


def test_fixture_poisoned_tuning_cache_fails(tmp_path):
    key = tuning.bucket_key(64, 64, 64, 16)
    cache = {"entries": {key: {
        "k_tile": 2 * tuning.max_k_tile(16), "block_m": 1, "block_n": 1,
        "source": "heuristic", "shape": [64, 64, 64], "n_bits": 16}}}
    path = tmp_path / "tuning.json"
    path.write_text(json.dumps(cache))
    vs = vmem.check_tuning_cache(str(path))
    assert "decode-window" in _contracts(vs)


# --------------------------------------------------- shipped kernels clean


def test_shipped_kernels_pass_all_widths_both_x64():
    # check_case internally traces each case under x64 off AND on
    cases = iter_cases(WIDTHS)
    assert len(cases) >= 4 * len(WIDTHS)
    violations = [v for c in cases for v in check_case(c)]
    assert violations == [], "\n".join(str(v) for v in violations)


@pytest.mark.parametrize("n", WIDTHS)
def test_shipped_schedules_prove_int32(n):
    bits, detail = overflow.prove_schedule(OnlinePrecision(n=n))
    assert bits <= 31, detail


@pytest.mark.parametrize("n", WIDTHS)
def test_decode_window_covers_legal_k_tiles(n):
    assert overflow.check_decode_windows(n, where=f"olm{n}") == []


def test_adder_tree_digit_bound_is_one():
    assert overflow.adder_tree_digit_bound() == 1


@pytest.mark.parametrize("n", WIDTHS)
def test_registered_tilings_fit_vmem(n):
    for label, (kt, bm, bn) in vmem.representative_tilings(n).items():
        assert vmem.check_matmul_tiling(n, kt, bm, bn, where=label) == []


def test_committed_tuning_cache_clean():
    assert vmem.check_tuning_cache() == []


# ----------------------------------------------------- width-aware budget


def test_lane_budget_width_aware():
    assert tuning.lane_budget(16) == tuning.LANE_BUDGET
    budgets = [tuning.lane_budget(n) for n in WIDTHS]
    assert budgets == sorted(budgets, reverse=True)  # shrinks with width
    for n in WIDTHS:
        b = tuning.lane_budget(n)
        assert b & (b - 1) == 0  # power of two


@pytest.mark.parametrize("n", WIDTHS)
def test_heuristic_tiling_respects_lane_budget(n):
    t = tuning.heuristic_tiling(512, 512, 512, n)
    assert t.block_m * t.block_n * t.k_tile <= tuning.lane_budget(n)


# --------------------------------------------------------------- AST lint


def _lint_src(tmp_path, relpath, source):
    p = tmp_path / relpath
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(source)
    return ast_lint.lint_file(str(p), str(tmp_path))


def test_ast_raw_dot_flagged(tmp_path):
    found = _lint_src(tmp_path, "src/repro/models/new_layer.py",
                      "import jax.numpy as jnp\n"
                      "def f(a, b):\n    return jnp.dot(a, b)\n")
    assert [(r, q) for r, _, _, q in found] == [("ast-raw-dot", "f")]


def test_ast_raw_dot_alias_cannot_dodge(tmp_path):
    found = _lint_src(tmp_path, "src/repro/models/new_layer.py",
                      "from jax.lax import dot_general as dg\n"
                      "def f(a, b, dims):\n    return dg(a, b, dims)\n")
    assert [r for r, _, _, _ in found] == ["ast-raw-dot"]


def test_ast_raw_dot_allowed_in_numerics(tmp_path):
    found = _lint_src(tmp_path, "src/repro/core/numerics.py",
                      "import jax.numpy as jnp\n"
                      "def f(a, b):\n    return jnp.dot(a, b)\n")
    assert found == []


def test_ast_x64_config_flagged(tmp_path):
    found = _lint_src(tmp_path, "src/repro/models/new_layer.py",
                      "import jax\n"
                      'jax.config.update("jax_enable_x64", True)\n')
    assert [r for r, _, _, _ in found] == ["ast-x64-config"]


def test_ast_transcendental_scale_flagged(tmp_path):
    found = _lint_src(tmp_path, "src/repro/kernels/common.py",
                      "import math\n"
                      "def f(x):\n    return math.log2(x)\n")
    assert [r for r, _, _, _ in found] == ["ast-transcendental-scale"]


def test_ast_serving_contraction_flagged(tmp_path):
    found = _lint_src(tmp_path, "src/repro/serving/sched.py",
                      "import jax.numpy as jnp\n"
                      "def f(a, b):\n    return jnp.einsum('ij,jk', a, b)\n")
    assert [(r, q) for r, _, _, q in found] == \
        [("ast-serving-contraction", "f")]


def test_ast_serving_raw_dot_double_flagged(tmp_path):
    # lax.dot_general in serving trips both the repo-wide raw-dot rule
    # and the serving-scheduler rule.
    found = _lint_src(tmp_path, "src/repro/serving/sched.py",
                      "from jax import lax\n"
                      "def f(a, b, d):\n    return lax.dot_general(a, b, d)\n")
    assert sorted(r for r, _, _, _ in found) == \
        ["ast-raw-dot", "ast-serving-contraction"]


@pytest.mark.parametrize("rel", ["src/repro/serving/faults.py",
                                 "src/repro/serving/degrade.py"])
def test_ast_serving_rule_covers_fault_tolerance_modules(tmp_path, rel):
    # the rule is prefix-scoped, so the Issue-9 fault-tolerance modules
    # are covered automatically — a contraction smuggled into either
    # would trip it
    found = _lint_src(tmp_path, rel,
                      "import jax.numpy as jnp\n"
                      "def f(a, b):\n    return jnp.matmul(a, b)\n")
    assert [r for r, _, _, _ in found] == ["ast-serving-contraction"], rel


def test_ast_einsum_fine_outside_serving(tmp_path):
    found = _lint_src(tmp_path, "src/repro/models/new_layer.py",
                      "import jax.numpy as jnp\n"
                      "def f(a, b):\n    return jnp.einsum('ij,jk', a, b)\n")
    assert found == []


def test_ast_repo_clean_under_committed_baseline():
    violations, _, unused = run_ast_lint()
    assert violations == [], "\n".join(str(v) for v in violations)
    assert unused == set(), f"stale baseline suppressions: {sorted(unused)}"


def test_baseline_key_invalidated_by_move():
    a = ast_lint.baseline_key("ast-raw-dot", "src/a.py", "f")
    assert a != ast_lint.baseline_key("ast-raw-dot", "src/b.py", "f")
    assert a != ast_lint.baseline_key("ast-raw-dot", "src/a.py", "g")


# -------------------------------------------------------- CLI + check_bench


def test_cli_ast_engine_exits_zero():
    r = subprocess.run([sys.executable, "tools/olmlint.py", "--engine", "ast"],
                       capture_output=True, text=True,
                       cwd=str(ast_lint._REPO_ROOT))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "olmlint: OK" in r.stdout


def test_cli_rejects_unregistered_width():
    r = subprocess.run([sys.executable, "tools/olmlint.py",
                        "--engine", "kernels", "--widths", "12"],
                       capture_output=True, text=True,
                       cwd=str(ast_lint._REPO_ROOT))
    assert r.returncode == 2


def test_check_bench_rejects_oversized_k_tile(tmp_path):
    tools_dir = os.path.join(ast_lint._REPO_ROOT, "tools")
    if tools_dir not in sys.path:
        sys.path.insert(0, tools_dir)
    import check_bench
    key = tuning.bucket_key(64, 64, 64, 16)
    cache = {"entries": {key: {
        "k_tile": 2 * tuning.max_k_tile(16), "block_m": 1, "block_n": 1,
        "source": "heuristic", "shape": [64, 64, 64], "n_bits": 16}}}
    path = tmp_path / "tuning.json"
    path.write_text(json.dumps(cache))
    with pytest.raises(check_bench.CheckFailure, match="decode window"):
        check_bench.check_tuning(str(path))


def test_violation_message_names_contract():
    vs = vmem.check_matmul_tiling(32, 256, 8, 8, where="fixture")
    msg = str(vs[0])
    assert "[vmem-budget]" in msg and "contract:" in msg


def test_jaxpr_violation_points_at_eqn():
    closed = jax.make_jaxpr(jnp.exp2)(jax.ShapeDtypeStruct((4,), jnp.float32))
    vs = check_jaxpr(closed, where="fixture")
    assert any("exp2" in v.detail for v in vs)
