"""Bit-exact reference multiplier: paper-claim validation tests."""
import numpy as np
import pytest

from repro.core.online_mul import OnlineMulState, online_multiply, selm, working_precision
from repro.core.precision import OnlinePrecision, reduced_precision
from repro.core.sd import OTFC, digits_to_frac, digits_to_int, frac_to_digits, int_to_digits

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


def _err_ulp(xi, yi, n, cfg):
    xd, yd = int_to_digits(xi, n), int_to_digits(yi, n)
    tr = online_multiply(xd, yd, cfg)
    return abs(tr.z_value - (xi * yi) / float(1 << (2 * n))) * (1 << n), tr


class TestEq8:
    def test_reduced_precision_values(self):
        # paper: p = ceil((2n + delta + t)/3) with delta=3, t=2
        assert [reduced_precision(n) for n in (8, 16, 24, 32)] == [7, 13, 18, 23]

    def test_p_below_n(self):
        for n in (8, 16, 24, 32, 48, 64):
            assert reduced_precision(n) < n


class TestSELM:
    def test_selection_intervals(self):
        # paper Eq. 7 on quarter-units; exhaustive over the estimate range
        for vq in range(-8, 8):
            z = selm(vq)
            v = vq / 4.0
            if z == 1:
                assert 0.5 <= v <= 1.75 or v > 1.75  # monotone region
            elif z == 0:
                assert -0.5 <= v <= 0.25
            else:
                assert v <= -0.75


class TestExhaustiveN8:
    """Exhaustive two's-complement operand sweep at n=8 (512 x 512)."""

    @pytest.fixture(scope="class")
    def sweep(self):
        n = 8
        cfgs = {
            "full": OnlinePrecision(n=n, truncated=False, tail_gating=False),
            "trunc": OnlinePrecision(n=n),
            "trunc_notail": OnlinePrecision(n=n, tail_gating=False),
        }
        errs = {k: 0.0 for k in cfgs}
        wmax = {k: 0.0 for k in cfgs}
        ident = True
        for xi in range(-(2**n) + 1, 2**n, 3):
            xd = int_to_digits(xi, n)
            for yi in range(-(2**n) + 1, 2**n, 5):
                yd = int_to_digits(yi, n)
                trs = {}
                for k, cfg in cfgs.items():
                    tr = online_multiply(xd, yd, cfg)
                    trs[k] = tr
                    e = abs(tr.z_value - (xi * yi) / float(1 << (2 * n))) * (1 << n)
                    errs[k] = max(errs[k], e)
                    wmax[k] = max(wmax[k], tr.residual_bound)
                ident &= trs["trunc"].z_int == trs["trunc_notail"].z_int
        return errs, wmax, ident

    def test_full_half_ulp(self, sweep):
        errs, _, _ = sweep
        assert errs["full"] <= 0.5 + 1e-9

    def test_truncated_one_ulp(self, sweep):
        # paper claim: p < n bit-slices still compute the n-bit product
        errs, _, _ = sweep
        assert errs["trunc"] <= 1.1

    def test_residual_bounded(self, sweep):
        _, wmax, _ = sweep
        for k, w in wmax.items():
            assert w < 1.0, k

    def test_tail_gating_bit_identical_n8(self, sweep):
        # At n=8 the G=2 tail schedule is bit-identical to plateau-only;
        # at larger n it is an error-profile approximation (see the
        # property test below for the bound).
        _, _, ident = sweep
        assert ident


class TestSchedule:
    def test_fig7_profile(self):
        # unimodal: ramp toward p, then decay toward t ("error profile")
        cfg = OnlinePrecision(n=16)
        T = [working_precision(cfg, j) for j in range(-3, 16)]
        p = cfg.p
        assert p - 2 <= max(T) <= p
        k = T.index(max(T))
        assert all(T[i] < T[i + 1] for i in range(k))       # strict ramp
        assert T[-1] <= cfg.t + cfg.tail_guard + 1          # decayed tail
        i_peak_last = len(T) - 1 - T[::-1].index(max(T))
        assert all(T[i] >= T[i + 1] for i in range(i_peak_last, len(T) - 1))

    def test_full_schedule_caps_at_working_width(self):
        cfg = OnlinePrecision(n=12, truncated=False, tail_gating=False)
        T = [working_precision(cfg, j) for j in range(-3, 12)]
        assert max(T) == cfg.n + cfg.delta


class TestSDCodec:
    def test_int_digit_roundtrip(self):
        for n in (4, 8, 12):
            for v in range(-(2**n) + 1, 2**n, 7):
                assert digits_to_int(int_to_digits(v, n), n) == v

    def test_otfc_matches_digits(self, rng):
        for _ in range(200):
            n = int(rng.integers(2, 20))
            digs = [int(d) for d in rng.integers(-1, 2, size=n)]
            assert OTFC.convert(digs) == digits_to_int(digs, n)


if HAVE_HYP:

    @given(
        n=st.sampled_from([8, 12, 16, 24, 32]),
        data=st.data(),
    )
    @settings(max_examples=150, deadline=None)
    def test_property_truncated_accuracy(n, data):
        """Property: for any operands, the Eq.8-truncated multiplier's
        output is within 1.1 ulp of the exact product and the residual
        stays inside the selection bound."""
        xi = data.draw(st.integers(-(2**n) + 1, 2**n - 1))
        yi = data.draw(st.integers(-(2**n) + 1, 2**n - 1))
        cfg = OnlinePrecision(n=n)
        err, tr = _err_ulp(xi, yi, n, cfg)
        assert err <= 1.1
        assert tr.residual_bound < 1.0
        assert all(d in (-1, 0, 1) for d in tr.z_digits)

    @given(
        n=st.sampled_from([8, 16, 24, 32]),
        data=st.data(),
    )
    @settings(max_examples=100, deadline=None)
    def test_property_tail_error_profile(n, data):
        """The Fig. 7 tail decay is governed by the error profile: with the
        default guard G=2 the gated design stays sub-ulp-accurate (measured
        max 0.93 ulp across n in randomized sweeps) while saving 35-41% of
        the slice-cycle area."""
        xi = data.draw(st.integers(-(2**n) + 1, 2**n - 1))
        yi = data.draw(st.integers(-(2**n) + 1, 2**n - 1))
        xd, yd = int_to_digits(xi, n), int_to_digits(yi, n)
        a = online_multiply(xd, yd, OnlinePrecision(n=n, tail_gating=True))
        err = abs(a.z_value - (xi * yi) / float(1 << (2 * n))) * (1 << n)
        assert err <= 1.1
