"""Subprocess worker for the `olm_matmul_distributed` bench.

The distributed bench must run on 1-device CI hosts and laptops, so it
cannot share the parent's already-initialized jax runtime: this worker
is spawned as a fresh `python -m benchmarks.distributed_worker`, forces
`--xla_force_host_platform_device_count=<devices>` BEFORE importing jax
(only stdlib is imported at module scope), and verifies the sharded olm
matmul contract on a real multi-device host mesh:

  * partition "m"/"n": output asserted BIT-IDENTICAL to the
    single-device `olm_matmul` for every requested mode (full and
    truncated) — rows carry ulp=0.0 and derived=1 as the identity
    marker.
  * partition "k": psum'd partials asserted within `olm_error_bound`
    — rows carry ulp = max(|err| / bound) (the consumed bound
    fraction) and derived=<device count>.

Per-row traffic columns come from `sharded_traffic`: bytes_moved is the
per-device LOCAL fused operand traffic, bytes_float the collective
bytes on the wire (0 for m/n; the f32 all-reduce total for k).

Output: one JSON object {"devices", "size", "rows"} on stdout (human
progress lines go to stderr), parsed by benchmarks/run.py and by the
tests/test_distributed_matmul.py subprocess smoke.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _parse_cases(widths: str, trunc: str):
    cases = [(int(n), None) for n in widths.split(",") if n]
    for pair in (p for p in trunc.split(",") if p):
        n, p = pair.split(":")
        cases.append((int(n), int(p)))
    return cases


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--size", type=int, default=64,
                    help="square GEMM dimension (M = N = K)")
    ap.add_argument("--widths", default="8,16,24,32",
                    help="comma-separated full-precision widths")
    ap.add_argument("--trunc", default="32:16",
                    help="comma-separated truncated n:p pairs")
    args = ap.parse_args(argv)

    # Must happen before the first jax import anywhere in this process.
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.devices}").strip()

    import jax
    import numpy as np

    from repro.kernels.online_dot.matmul import olm_error_bound, olm_matmul
    from repro.kernels.online_dot.matmul_sharded import (olm_matmul_sharded,
                                                        sharded_traffic)

    if len(jax.devices()) < args.devices:
        print(f"worker: forced {args.devices} devices but jax sees "
              f"{len(jax.devices())}", file=sys.stderr)
        return 2

    mesh = jax.make_mesh((args.devices,), ("model",))
    S = args.size
    rng = np.random.default_rng(0)
    x = rng.standard_normal((S, S)).astype(np.float32)
    w = rng.standard_normal((S, S)).astype(np.float32)
    exact = x.astype(np.float64) @ w.astype(np.float64)

    rows = []
    for n, p in _parse_cases(args.widths, args.trunc):
        label = f"olm{n}" if p is None else f"olm{n}t{p}"
        ref = np.asarray(olm_matmul(x, w, n_bits=n, trunc=p))
        bound = np.asarray(olm_error_bound(x, w, n_bits=n, trunc=p))
        for part in ("m", "n", "k"):
            t0 = time.perf_counter()
            out = np.asarray(olm_matmul_sharded(
                x, w, mesh=mesh, partition=part, n_bits=n, trunc=p))
            us = (time.perf_counter() - t0) * 1e6
            tr = sharded_traffic(S, S, S, partition=part,
                                 devices=args.devices, n_bits=n, trunc=p)
            if part in ("m", "n"):
                if not np.array_equal(out, ref):
                    print(f"worker: {label}/{part} NOT bit-identical to "
                          "single-device", file=sys.stderr)
                    return 1
                ulp, derived = 0.0, 1
            else:
                frac = float((np.abs(out - exact) / bound).max())
                if not frac <= 1.0:
                    print(f"worker: {label}/k outside olm_error_bound "
                          f"({frac:.3f}x)", file=sys.stderr)
                    return 1
                ulp, derived = round(frac, 4), args.devices
            print(f"  {label:>9}/{part}: ulp={ulp} "
                  f"local={tr['local']['fused_bytes']}B "
                  f"wire={tr['collective_bytes']}B", file=sys.stderr)
            rows.append({
                "op": f"olm_matmul_distributed/{label}/{part}",
                "n": n, "k": S, "us": round(us, 2), "ulp": ulp,
                "derived": derived,
                "bytes_moved": int(tr["local"]["fused_bytes"]),
                "bytes_float": int(tr["collective_bytes"]),
            })
    print(json.dumps({"devices": args.devices, "size": S, "rows": rows}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
