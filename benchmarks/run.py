"""Benchmark harness — one function per paper table + framework benches.

Prints ``name,us_per_call,derived`` CSV rows and a human-readable summary
per table. Every bench also returns machine-readable rows
``{op, n, k, us, ulp, derived}`` (``ulp``/``k`` null where not
applicable); with ``--json-dir DIR`` the harness writes one
``BENCH_<name>.json`` per bench there, so the perf/accuracy trajectory is
tracked across PRs (``make bench-json``). Heavy benches keep sizes
CPU-friendly; the dry-run/roofline artifacts cover the production scale.

  PYTHONPATH=src python -m benchmarks.run [--only table1,table3] \
      [--json-dir results/bench]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _timeit(fn, *args, repeat=3, number=1):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            out = fn(*args)
        best = min(best, (time.perf_counter() - t0) / number)
    return best * 1e6, out


def _row(op, *, n=None, k=None, us=0.0, ulp=None, derived=None,
         bytes_moved=None, bytes_float=None):
    r = {"op": op, "n": n, "k": k, "us": round(us, 2), "ulp": ulp,
         "derived": derived}
    if bytes_moved is not None:
        r["bytes_moved"] = int(bytes_moved)
    if bytes_float is not None:
        r["bytes_float"] = int(bytes_float)
    return r


def table1_area_power():
    """Paper Table I: pipelined online multiplier, full vs reduced working
    precision — latches/area/power, model vs paper."""
    from repro.core.hwmodel import PAPER_TABLE1, online_multiplier_cost
    from repro.core.precision import OnlinePrecision
    print("\n== Table I: full vs reduced working precision (model | paper) ==")
    print(f"{'n':>3} {'metric':>8} {'full':>10} {'reduced':>10} "
          f"{'save%':>7} {'paper_save%':>11}")
    rows = []
    for n in (8, 16, 24, 32):
        t0 = time.perf_counter()
        full = online_multiplier_cost(
            OnlinePrecision(n=n, truncated=False, tail_gating=False))
        red = online_multiplier_cost(OnlinePrecision(n=n))
        us = (time.perf_counter() - t0) * 1e6
        for metric, fu, re_ in (("latches", full.latches, red.latches),
                                ("area", full.area, red.area),
                                ("power", full.power, red.power)):
            save = 100 * (1 - re_ / fu)
            p = PAPER_TABLE1[metric]
            psave = 100 * (1 - p["reduced"][n] / p["full"][n])
            print(f"{n:>3} {metric:>8} {fu:>10.0f} {re_:>10.0f} "
                  f"{save:>7.1f} {psave:>11.1f}")
            rows.append(_row(f"table1/{metric}", n=n, us=us,
                             derived=round(save, 2)))
    for r in rows:
        print(f"{r['op']}/n{r['n']},{r['us']:.1f},{r['derived']:.2f}")
    return rows


def table2_multiplier_comparison():
    """Paper Table II: 8-bit multiplier families (model vs paper)."""
    from repro.core.hwmodel import (PAPER_TABLE2, array_multiplier_cost,
                                    nonpipelined_online_cost,
                                    online_multiplier_cost,
                                    serial_parallel_cost)
    from repro.core.precision import OnlinePrecision
    print("\n== Table II: 8-bit multiplier comparison (model | paper) ==")
    designs = {
        "serial-parallel": serial_parallel_cost(8),
        "array": array_multiplier_cost(8),
        "online-iterative": nonpipelined_online_cost(8),
        "olm-pipelined-full": online_multiplier_cost(
            OnlinePrecision(n=8, truncated=False, tail_gating=False)),
        "olm-pipelined-reduced": online_multiplier_cost(OnlinePrecision(n=8)),
    }
    print(f"{'design':>22} {'latches':>8} {'area':>9} {'power':>10} "
          f"{'paper(latch/area/power)':>26}")
    rows = []
    for name, c in designs.items():
        p = PAPER_TABLE2[name]
        print(f"{name:>22} {c.latches:>8} {c.area:>9.0f} {c.power:>10.0f} "
              f"{p['latches']:>8}/{p['area']:>8.1f}/{p['power']:>8.1f}")
        print(f"table2/{name},0.0,{c.area:.2f}")
        rows.append(_row(f"table2/{name}", n=8, derived=round(c.area, 2)))
    return rows


def table3_cycles():
    """Paper Table III: cycles to process k=8 vectors, measured on the
    cycle-accurate pipeline simulator vs closed forms."""
    from repro.core.pipeline import run_pipeline
    from repro.core.precision import OnlinePrecision
    rng = np.random.default_rng(0)
    k = 8
    print("\n== Table III: clock cycles for k=8 vector stream ==")
    print(f"{'n':>3} {'SP(n+1)k':>9} {'array nk':>9} {'online':>7} "
          f"{'pipelined':>10} {'simulated':>10}")
    rows = []
    for n in (8, 16, 24, 32):
        pairs = [([int(d) for d in rng.integers(-1, 2, n)],
                  [int(d) for d in rng.integers(-1, 2, n)]) for _ in range(k)]
        us, run = _timeit(run_pipeline, pairs, OnlinePrecision(n=n), repeat=1)
        sp, ar = (n + 1) * k, n * k
        ol, pl = (n + 4) * k, (n + 4) + (k - 1)
        assert run.cycles == pl
        print(f"{n:>3} {sp:>9} {ar:>9} {ol:>7} {pl:>10} {run.cycles:>10}")
        print(f"table3/n{n},{us:.1f},{run.cycles}")
        rows.append(_row("table3", n=n, k=k, us=us, derived=run.cycles))
    return rows


def error_profile():
    """Eq. 8 validation: empirical max error vs working precision."""
    from repro.core.online_mul import online_multiply
    from repro.core.precision import OnlinePrecision, reduced_precision
    from repro.core.sd import int_to_digits
    rng = np.random.default_rng(7)
    print("\n== Error profile: |z - x*y| in output ulp (randomized) ==")
    print(f"{'n':>3} {'p(Eq.8)':>8} {'full':>7} {'truncated':>10} "
          f"{'trunc+tail(G=2)':>16}")
    rows = []
    for n in (8, 16, 24, 32):
        errs = {}
        for label, cfg in (
                ("full", OnlinePrecision(n=n, truncated=False, tail_gating=False)),
                ("trunc", OnlinePrecision(n=n, tail_gating=False)),
                ("tail", OnlinePrecision(n=n))):
            e = 0.0
            for _ in range(800):
                xi = int(rng.integers(-(2**n) + 1, 2**n))
                yi = int(rng.integers(-(2**n) + 1, 2**n))
                tr = online_multiply(int_to_digits(xi, n),
                                     int_to_digits(yi, n), cfg)
                e = max(e, abs(tr.z_value - (xi * yi) / float(1 << (2 * n)))
                        * (1 << n))
            errs[label] = e
        print(f"{n:>3} {reduced_precision(n):>8} {errs['full']:>7.3f} "
              f"{errs['trunc']:>10.3f} {errs['tail']:>16.3f}")
        print(f"error_profile/n{n},0.0,{errs['tail']:.4f}")
        rows.append(_row("error_profile", n=n, ulp=round(errs["tail"], 4)))
    return rows


def tpmm_bench():
    """TPU adaptation: truncated digit-plane matmul — MXU-op savings and
    error at each delivered precision (DESIGN.md §2)."""
    import jax.numpy as jnp
    from repro.kernels.tpmm.ops import tpmm, tpmm_cost_model
    rng = np.random.default_rng(0)
    print("\n== tpmm: plane-matmul savings vs delivered precision ==")
    print(f"{'n_bits':>6} {'planes':>7} {'pairs':>11} {'save%':>7} "
          f"{'rel_err':>9} {'us':>9}")
    rows = []
    for nb in (8, 16, 24, 32):
        dim = 256 if nb <= 16 else 128  # n=24/32 run many plane pairs
        a = rng.standard_normal((dim, dim)).astype(np.float32)
        b = rng.standard_normal((dim, dim)).astype(np.float32)
        exact = a @ b
        cm = tpmm_cost_model(nb)
        pairs = f"{cm['pair_matmuls_truncated']}/{cm['pair_matmuls_full']}"
        if nb > 28:  # int32 quantizer limit; f32 inputs cap at 24 bits
            print(f"{nb:>6} {cm['planes']:>7} {pairs:>11} "
                  f"{cm['mxu_savings_pct']:>7.1f} {'(cost model)':>9} {'-':>9}")
            print(f"tpmm/n{nb},0.0,{cm['mxu_savings_pct']:.2f}")
            rows.append(_row("tpmm", n=nb, k=dim,
                             derived=round(cm["mxu_savings_pct"], 2)))
            continue
        fn = lambda: tpmm(jnp.asarray(a), jnp.asarray(b), n_bits=nb,
                          use_pallas=False)
        fn()  # compile
        us, got = _timeit(fn, repeat=2)
        rel = float(np.max(np.abs(np.asarray(got) - exact)) / np.abs(exact).max())
        print(f"{nb:>6} {cm['planes']:>7} {pairs:>11} "
              f"{cm['mxu_savings_pct']:>7.1f} {rel:>9.2e} {us:>9.1f}")
        print(f"tpmm/n{nb},{us:.1f},{cm['mxu_savings_pct']:.2f}")
        rows.append(_row("tpmm", n=nb, k=dim, us=us, ulp=rel,
                         derived=round(cm["mxu_savings_pct"], 2)))
    return rows


def online_dot_bench():
    """Fused inner-product array kernel: K multiplier lanes + online adder
    tree in one Pallas call, swept over (k, n). Reports kernel time and
    worst-case value error vs the exact dot (bound: 1.1 ulp per lane)."""
    from repro.core.precision import OnlinePrecision
    from repro.kernels.online_dot.ops import dot_stream_length, online_dot
    rng = np.random.default_rng(3)
    B = 8
    print("\n== online_dot: fused array kernel (B=8 rows) ==")
    print(f"{'k':>4} {'n':>3} {'stream':>7} {'us':>10} {'max_ulp':>9} "
          f"{'ulp_bound':>10}")
    rows = []
    for k in (8, 64, 256):
        for n in (8, 16, 32):
            xd = rng.integers(-1, 2, size=(B, k, n)).astype(np.int32)
            yd = rng.integers(-1, 2, size=(B, k, n)).astype(np.int32)
            cfg = OnlinePrecision(n=n)
            fn = lambda: online_dot(xd, yd, cfg, use_pallas=True, block_b=B)
            fn()  # compile
            us, (z, val) = _timeit(fn, repeat=2)
            w = 0.5 ** np.arange(1, n + 1)
            exact = ((xd @ w) * (yd @ w)).sum(axis=1)
            ulp = float(np.max(np.abs(val - exact)) * (1 << n))
            print(f"{k:>4} {n:>3} {dot_stream_length(n, k):>7} {us:>10.1f} "
                  f"{ulp:>9.3f} {1.1 * k:>10.1f}")
            print(f"online_dot/k{k}_n{n},{us:.1f},{ulp:.4f}")
            rows.append(_row("online_dot", n=n, k=k, us=us,
                             ulp=round(ulp, 4)))
    return rows


def olm_matmul_bench():
    """DotEngine's olm lowering: the grid-tiled Pallas kernel (operand
    digit grids loaded once per output tile, host-side quantize) against
    the broadcast oracle (full (M*N, k_tile, n) fan-out — the pre-grid
    front-end and the engine's in-model default use_pallas=False path).
    Reports wall time, worst-case |error| vs the exact f32 matmul, how
    much of the documented olm_error_bound budget that error uses
    (of_bound <= 1.0 is the tested guarantee), and both operand-traffic
    columns per path (matmul.digit_traffic): the digit-grid bytes this
    path moves and the float-tile bytes the fused quantize-in-kernel
    path would move instead (digit / n_bits — see olm_matmul_fused for
    the fused path's own wall clock)."""
    import jax.numpy as jnp
    from repro.kernels.online_dot.matmul import (DEFAULT_BLOCK_M,
                                                 DEFAULT_BLOCK_N,
                                                 digit_traffic,
                                                 olm_error_bound, olm_matmul)
    rng = np.random.default_rng(5)
    print("\n== olm_matmul: model GEMMs through the array lowering "
          "(grid kernel vs broadcast oracle) ==")
    print(f"{'MxKxN':>12} {'n':>3} {'path':>6} {'us':>10} {'max_err':>10} "
          f"{'of_bound':>9} {'digit_B':>10} {'float_B':>9} {'reuse':>6}")
    rows = []
    cases = (((8, 16, 8), False), ((8, 64, 8), False),
             # acceptance case: M=N=64, n=16 — the digit-traffic cut
             # (>= min(block_m, block_n)/2 x) is asserted below; wall
             # clock is recorded in the JSON rows for the trajectory but
             # not gated (too noisy on shared CI runners)
             ((64, 32, 64), True))
    for (M, K, N), pallas_too in cases:
        a = rng.standard_normal((M, K)).astype(np.float32)
        b = rng.standard_normal((K, N)).astype(np.float32)
        # f64 reference: an f32 `a @ b` would contribute its own BLAS
        # rounding (order-dependent across machines) to the ulp column,
        # which at n = 24/32 is the same order as the measured error —
        # the CI baseline diff needs this column machine-stable
        exact = a.astype(np.float64) @ b.astype(np.float64)
        for nb in (8, 16, 24, 32):   # every registered MATMUL_MODES width
            traffic = digit_traffic(M, N, K, n_bits=nb)
            bound = np.asarray(olm_error_bound(jnp.asarray(a),
                                               jnp.asarray(b), n_bits=nb))
            paths = [("bcast", False, traffic["broadcast_bytes"], 1.0)]
            if pallas_too:
                paths.append(("grid", True, traffic["grid_bytes"],
                              traffic["reuse"]))
            for label, use, op_bytes, reuse in paths:
                # np.asarray blocks on the async dispatch, so us is the
                # real wall clock, comparable across paths
                fn = lambda: np.asarray(
                    olm_matmul(jnp.asarray(a), jnp.asarray(b),
                               n_bits=nb, use_pallas=use, quantize="host"))
                fn()  # compile
                us, got = _timeit(fn, repeat=2)
                err = np.abs(np.asarray(got) - exact)
                used = float((err / bound).max())
                print(f"{M:>4}x{K:>3}x{N:>3} {nb:>3} {label:>6} {us:>10.1f} "
                      f"{err.max():>10.2e} {used:>9.3f} {op_bytes:>10} "
                      f"{traffic['fused_bytes']:>9} {reuse:>6.1f}")
                print(f"olm_matmul/{M}x{K}x{N}_n{nb}_{label},"
                      f"{us:.1f},{used:.4f}")
                rows.append(_row(f"olm_matmul/{label}", n=nb, k=K, us=us,
                                 ulp=round(used, 4),
                                 derived=round(reuse, 2),
                                 bytes_moved=op_bytes,
                                 bytes_float=traffic["fused_bytes"]))
    blk = min(DEFAULT_BLOCK_M, DEFAULT_BLOCK_N)
    grid_rows = [r for r in rows if r["op"] == "olm_matmul/grid"]
    bc = {(r["n"], r["k"]): r for r in rows if r["op"] == "olm_matmul/bcast"}
    for r in grid_rows:
        mate = bc[(r["n"], r["k"])]
        assert r["bytes_moved"] * (blk // 2) <= mate["bytes_moved"], \
            "grid kernel must cut digit-grid traffic >= min(bm,bn)/2 x"
    return rows


def olm_matmul_fused_bench():
    """Quantize-in-kernel sweep: grid-host-quantize (pre-expanded digit
    grids cross HBM) vs grid-in-kernel-quantize (raw float tiles cross
    HBM, sd_quantize runs in the kernel prologue) vs the broadcast
    oracle, at the default shape/tiling, for every registered olm mode
    width (8/16/24/32 — n = 24/32 exercise the wide two-limb/int64
    stream decode). Emits bytes_moved and wall time per path; asserts
    the three outputs are bit-identical and that the fused path moves
    >= 4x (actually n_bits x) fewer operand bytes than the host-
    quantize grid path — tools/check_bench.py re-checks that from the
    JSON in CI so the traffic win can't silently regress at any
    width."""
    import jax.numpy as jnp
    from repro.kernels.online_dot.matmul import digit_traffic, olm_matmul
    rng = np.random.default_rng(11)
    M, K, N = 64, 32, 64
    print("\n== olm_matmul_fused: where quantization runs "
          "(host grids vs in-kernel float tiles vs broadcast oracle) ==")
    print(f"{'MxKxN':>12} {'n':>3} {'path':>11} {'us':>10} "
          f"{'bytes_moved':>12} {'vs_host':>8}")
    rows = []
    a = rng.standard_normal((M, K)).astype(np.float32)
    b = rng.standard_normal((K, N)).astype(np.float32)
    for nb in (8, 16, 24, 32):       # every registered MATMUL_MODES width
        traffic = digit_traffic(M, N, K, n_bits=nb)
        paths = (
            ("bcast", dict(use_pallas=False), traffic["broadcast_bytes"]),
            ("grid-host", dict(use_pallas=True, quantize="host"),
             traffic["grid_bytes"]),
            ("grid-fused", dict(use_pallas=True, quantize="kernel"),
             traffic["fused_bytes"]),
        )
        outs = {}
        for label, kw, op_bytes in paths:
            fn = lambda: np.asarray(
                olm_matmul(jnp.asarray(a), jnp.asarray(b), n_bits=nb, **kw))
            fn()  # compile
            us, got = _timeit(fn, repeat=2)
            outs[label] = got
            vs_host = traffic["grid_bytes"] / op_bytes
            print(f"{M:>4}x{K:>3}x{N:>3} {nb:>3} {label:>11} {us:>10.1f} "
                  f"{op_bytes:>12} {vs_host:>8.1f}")
            print(f"olm_matmul_fused/{M}x{K}x{N}_n{nb}_{label},"
                  f"{us:.1f},{op_bytes}")
            rows.append(_row(f"olm_matmul_fused/{label}", n=nb, k=K, us=us,
                             derived=round(vs_host, 2),
                             bytes_moved=op_bytes))
        # one numerics: quantize placement must not change a single bit
        np.testing.assert_array_equal(outs["grid-fused"], outs["grid-host"])
        np.testing.assert_array_equal(outs["grid-fused"], outs["bcast"])
        # the acceptance gate: in-kernel quantize cuts operand traffic
        # by n_bits x (>= 4x at every supported width) vs host quantize
        assert traffic["fused_bytes"] * 4 <= traffic["grid_bytes"], \
            "fused path must move >= 4x fewer operand bytes than host"
        assert traffic["fused_bytes"] * nb == traffic["grid_bytes"]
    return rows


def olm_matmul_truncated_bench():
    """Truncated working-precision tiers: every olm{n}t{p} mode vs its
    same-width full mode at the default shape/tiling. Asserts the tier
    is bit-identical to the p-digit array (working precision IS the
    mode), that max |err| vs the f64 oracle stays inside the extended
    olm_error_bound truncation term, and that the digit-grid operand
    bytes drop by exactly p/n — the ledger tools/check_bench.py
    re-gates from the committed JSON. Also prints the hwmodel
    activity/area/latency delta per tier (paper Table I axis)."""
    import jax.numpy as jnp
    from repro.core.hwmodel import truncated_delta
    from repro.core.numerics import TRUNCATED_SPECS
    from repro.kernels.online_dot.matmul import (digit_traffic,
                                                 olm_error_bound,
                                                 olm_matmul)
    rng = np.random.default_rng(13)
    M, K, N = 64, 32, 64
    a = rng.standard_normal((M, K)).astype(np.float32)
    b = rng.standard_normal((K, N)).astype(np.float32)
    oracle = a.astype(np.float64) @ b.astype(np.float64)
    print("\n== olm_matmul_truncated: olm{n}t{p} tiers vs full modes ==")
    print(f"{'mode':>10} {'us':>10} {'grid_bytes':>11} {'cut':>6} "
          f"{'err/bound':>10}")
    rows = []

    def run_mode(nb, trunc=None):
        fn = lambda: np.asarray(olm_matmul(
            jnp.asarray(a), jnp.asarray(b), n_bits=nb, trunc=trunc,
            use_pallas=True, quantize="kernel"))
        fn()  # compile
        us, got = _timeit(fn, repeat=2)
        bound = np.asarray(olm_error_bound(
            jnp.asarray(a), jnp.asarray(b), n_bits=nb, trunc=trunc))
        frac = float(np.max(np.abs(got - oracle) / bound))
        traffic = digit_traffic(M, N, K, n_bits=nb, trunc=trunc)
        return us, got, frac, traffic["grid_bytes"]

    full = {}
    for nb in sorted({n for n, _ in TRUNCATED_SPECS}):
        us, got, frac, gbytes = run_mode(nb)
        full[nb] = (got, gbytes)
        assert frac <= 1.0, f"olm{nb} exceeds its documented bound"
        print(f"{f'olm{nb}':>10} {us:>10.1f} {gbytes:>11} {1.0:>6.2f} "
              f"{frac:>10.3f}")
        rows.append(_row("olm_matmul_truncated/full", n=nb, k=K, us=us,
                         ulp=round(frac, 4), derived=1.0,
                         bytes_moved=gbytes))
    for nb, p in TRUNCATED_SPECS:
        us, got, frac, gbytes = run_mode(nb, trunc=p)
        # working precision IS the mode: bit-identical to the p-array
        ident = np.asarray(olm_matmul(jnp.asarray(a), jnp.asarray(b),
                                      n_bits=p, use_pallas=True,
                                      quantize="kernel"))
        np.testing.assert_array_equal(got, ident)
        assert frac <= 1.0, \
            f"olm{nb}t{p} exceeds the extended truncation bound"
        # the acceptance gate: digit operand bytes cut by exactly p/n
        assert gbytes * nb == full[nb][1] * p, \
            f"olm{nb}t{p} grid bytes must be p/n of the full mode's"
        cut = full[nb][1] / gbytes
        print(f"{f'olm{nb}t{p}':>10} {us:>10.1f} {gbytes:>11} "
              f"{cut:>6.2f} {frac:>10.3f}")
        rows.append(_row(f"olm_matmul_truncated/t{p}", n=nb, k=K, us=us,
                         ulp=round(frac, 4), derived=round(cut, 4),
                         bytes_moved=gbytes))
        d = truncated_delta(nb, p)
        print(f"  hw delta olm{nb}t{p}: activity -{d['activity_save_pct']}% "
              f"({d['full_activity']} -> {d['trunc_activity']} slices), "
              f"area -{d['area_save_pct']}%, power -{d['power_save_pct']}%, "
              f"latency {d['full_latency']} -> {d['trunc_latency']} cycles "
              f"(-{d['latency_delta']})")
        rows.append(_row(f"olm_matmul_truncated/hw_t{p}", n=nb,
                         derived=d["activity_save_pct"]))
    return rows


def serve_replay_bench():
    """Traffic replay through the serving engine: a seeded arrival
    process (serving.replay) drives the paged-KV engine and the
    contiguous-cache oracle through the identical workload. Latency
    rows are in scheduler steps — a pure function of the workload and
    scheduler logic (eos_id=None, so steps never depend on sampled
    token values) — which is what lets tools/check_bench.py diff them
    against the committed baseline on any host; wall time is recorded
    in `us` for the trajectory but never gated. KV rows account bytes
    actually resident for attention K/V under each layout: the paged
    pool must sit strictly below the contiguous slots*max_len figure.
    The two runs must also be token-identical (asserted here and
    re-tested per dot_mode in tests/test_serving_engine.py)."""
    import jax
    from repro.configs import smoke_config
    from repro.models.model import Model
    from repro.serving import (ReplayConfig, ServeEngine, build_workload,
                               run_replay)
    cfg = smoke_config("internlm2_1_8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rc = ReplayConfig(seed=0, n_requests=24, mean_interarrival_steps=2.0,
                      prompt_len_range=(4, 24), max_new_range=(4, 10),
                      vocab=cfg.vocab_size)
    workload = build_workload(rc)
    slots, max_len = 4, 64
    # 20 usable blocks = every lane at its workload-peak depth at once
    # (ceil((24+10)/8) = 5 blocks x 4 lanes), so no cache_full at 65% of
    # the contiguous residency; +1 for the trash block
    layouts = (
        ("paged", dict(kv_layout="paged", kv_block_size=8, kv_blocks=21)),
        ("contig", dict(kv_layout="contiguous")),
    )
    print("\n== serve_replay: seeded traffic through the serving engine "
          "(paged KV vs contiguous oracle) ==")
    engines, reports, outputs = {}, {}, {}
    for label, kw in layouts:
        eng = ServeEngine(model, params, slots=slots, max_len=max_len,
                          dot_tiling="auto", **kw)
        done, rep = run_replay(eng, workload)
        assert rep["n"] == rc.n_requests, "replay must complete the workload"
        engines[label], reports[label] = eng, rep
        outputs[label] = {r.rid: tuple(r.output) for r in done}
        print(f"{label:>7}: ttft p50/p99 = {rep['ttft_steps_p50']:.1f}/"
              f"{rep['ttft_steps_p99']:.1f} steps, e2e p50/p99 = "
              f"{rep['e2e_steps_p50']:.1f}/{rep['e2e_steps_p99']:.1f}, "
              f"{rep['tokens_per_step']:.3f} tok/step, "
              f"wall {rep['wall_s']:.2f}s")
    assert outputs["paged"] == outputs["contig"], \
        "paged decode must be token-identical to the contiguous oracle"
    kvp = engines["paged"].kv_report()
    kvc = engines["contig"].kv_report()
    assert kvp["kv_bytes_resident"] < kvc["kv_bytes_resident"], \
        "paged KV residency must sit strictly below contiguous"
    rep = reports["paged"]
    wall_us = rep["wall_s"] * 1e6
    ratio = kvp["kv_bytes_resident"] / kvc["kv_bytes_resident"]
    print(f"kv resident: paged {kvp['kv_bytes_resident']} B vs contiguous "
          f"{kvc['kv_bytes_resident']} B ({100 * ratio:.1f}%), peak blocks "
          f"{kvp['kv_blocks_peak_used']}/{kvp['kv_blocks_usable']}, "
          f"prefill compiles {engines['paged'].prefill_traces}")
    rows = [
        _row("serve_replay/ttft_p50", derived=rep["ttft_steps_p50"]),
        _row("serve_replay/ttft_p99", derived=rep["ttft_steps_p99"]),
        _row("serve_replay/e2e_p50", derived=rep["e2e_steps_p50"]),
        _row("serve_replay/e2e_p99", derived=rep["e2e_steps_p99"]),
        _row("serve_replay/tokens_per_step", us=wall_us,
             derived=rep["tokens_per_step"]),
        _row("serve_replay/completed", derived=rep["n"]),
        _row("serve_replay/cache_full", derived=rep["n_cache_full"]),
        _row("serve_replay/prefill_compiles",
             derived=engines["paged"].prefill_traces),
        _row("serve_replay/blocks_peak",
             derived=kvp["kv_blocks_peak_used"]),
        _row("serve_replay/kv_paged",
             bytes_moved=kvp["kv_bytes_resident"],
             bytes_float=kvp["kv_bytes_contiguous"],
             derived=round(ratio, 4)),
        _row("serve_replay/kv_contig",
             bytes_moved=kvc["kv_bytes_resident"]),
    ]
    for r in rows:
        print(f"{r['op']},{r['us']:.1f},{r['derived']}")
    return rows


def serve_faults_bench():
    """Chaos bench: seeded fault plans (serving/faults.py) against the
    fault-tolerant serving engine, two seeds. For each seed the same
    workload runs fault-free (reference) and under the identical fault
    plan; the bench asserts the robustness contract — every injected
    fault resolves to an explicit finish_reason or a recorded recovery
    (retry/preempt/repair/degrade), non-faulted requests keep exact
    token identity with the fault-free run, preempted-then-recomputed
    requests are bit-identical to it, and deadline-expired requests
    emit a clean prefix — then emits the resolution counters as exact
    integer rows for the committed baseline. Determinism across runs is
    double-checked in-process for seed 0 (each engine re-jits its entry
    points, so replays are compile-bound; one double-run keeps the
    bench inside the CI budget), and for both seeds every CI run is an
    across-runs/across-hosts determinism check by construction: the
    integer rows must match `results/baseline/` exactly
    (tools/check_bench.py --only faults re-checks the invariants)."""
    import jax
    from repro.configs import smoke_config
    from repro.models.model import Model
    from repro.serving import (FaultConfig, FaultInjector, ReplayConfig,
                               ServeEngine, build_fault_plan,
                               build_workload, run_replay)
    cfg = smoke_config("internlm2_1_8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ladder = [model.eng.mode, "olm32t24", "olm32t16"]
    known = {"eos", "length", "max_len", "cache_full", "deadline",
             "rejected", "numerics", "failed"}

    def make_engine():
        return ServeEngine(model, params, slots=4, max_len=64,
                           kv_layout="paged", kv_block_size=8, kv_blocks=21,
                           max_queue=8, preempt=True, numerics_check=True,
                           integrity_audit=True, degrade_ladder=ladder)

    print("\n== serve_faults: seeded fault injection against the serving "
          "engine (2 seeds, faulted vs fault-free reference) ==")
    rows = []
    for seed in (0, 1):
        rc = ReplayConfig(seed=seed, n_requests=20,
                          mean_interarrival_steps=2.0,
                          prompt_len_range=(4, 16), max_new_range=(4, 10),
                          vocab=cfg.vocab_size, deadline_every=6,
                          deadline_steps=30, priority_levels=2)
        workload = build_workload(rc)
        ref_done, ref_rep = run_replay(make_engine(), workload)
        ref = {r.rid: (tuple(r.output), r.finish_reason) for r in ref_done}
        # keep all fault events inside the busy phase of the replay so
        # none can defer past the drain (horizon is a pure function of
        # the workload: steps_total is deterministic)
        fc = FaultConfig(seed=seed,
                         horizon_steps=max(10,
                                           int(ref_rep["steps_total"]) * 2 // 3),
                         exhaust_blocks=16, exhaust_hold_steps=6)

        def faulted_run():
            eng = make_engine()
            inj = FaultInjector(build_fault_plan(fc))
            done, rep = run_replay(eng, workload, faults=inj)
            key = {r.rid: (tuple(r.output), r.finish_reason, r.n_preempts,
                           r.n_retries, r.degrade_rung, r.served_tier)
                   for r in done}
            return eng, inj, done, rep, key

        eng, inj, done, rep, key1 = faulted_run()
        if seed == 0:
            _, inj2, _, rep2, key2 = faulted_run()
            assert key1 == key2 and inj.summary() == inj2.summary(), \
                "seeded fault replay must be deterministic across runs"
            assert {k: v for k, v in rep.items() if k != "wall_s"} == \
                {k: v for k, v in rep2.items() if k != "wall_s"}
        stats, ctr = inj.summary(), eng.counters
        for fam in ("exhaust", "corrupt", "nan", "prefill_fail"):
            assert stats.get(fam, 0) >= 1, \
                f"fault family {fam!r} never fired (seed {seed})"
        assert len(done) == rc.n_requests \
            and all(r.finish_reason in known for r in done), \
            "every request must resolve to an explicit finish_reason"
        # injected faults -> explicit finish or recorded recovery
        assert rep["n_numerics"] == stats["nan"]
        assert ctr["table_repairs"] == stats["corrupt"]
        assert ctr["prefill_retries"] == stats["prefill_fail"]
        assert ctr["preempted"] >= 1, \
            "block exhaustion must preempt at least one lane"
        identical = 0
        for r in done:
            out, reason = tuple(r.output), r.finish_reason
            if (out, reason) == ref[r.rid]:
                identical += 1
                continue
            assert (r.n_preempts or r.n_retries or r.degrade_rung
                    or reason in ("numerics", "deadline", "rejected",
                                  "cache_full", "failed")), \
                f"rid {r.rid} diverged with no recorded fault or recovery"
            if r.n_preempts and not r.degrade_rung \
                    and reason == ref[r.rid][1]:
                assert out == ref[r.rid][0], \
                    "preempted+recomputed streams must be bit-identical"
            if reason == "deadline" and not r.degrade_rung:
                assert out == ref[r.rid][0][:len(out)], \
                    "a deadline-expired stream must be a clean prefix"
        kvr = eng.kv_report()
        assert kvr["integrity_ok"] and kvr["kv_blocks_held"] == 0, \
            "post-run block accounting must balance"
        print(f"seed {seed}: injected {stats} -> counters "
              f"{dict(sorted(ctr.items()))}, {identical}/{rc.n_requests} "
              f"token-identical to fault-free, wall {rep['wall_s']:.2f}s")
        pre = f"serve_faults/s{seed}/"
        rows += [
            _row(pre + "completed", us=rep["wall_s"] * 1e6,
                 derived=rep["n"]),
            _row(pre + "steps_total", derived=rep["steps_total"]),
            _row(pre + "injected_exhaust", derived=stats.get("exhaust", 0)),
            _row(pre + "injected_corrupt", derived=stats.get("corrupt", 0)),
            _row(pre + "injected_nan", derived=stats.get("nan", 0)),
            _row(pre + "injected_prefill_fail",
                 derived=stats.get("prefill_fail", 0)),
            _row(pre + "preempted", derived=int(ctr["preempted"])),
            _row(pre + "table_repairs", derived=int(ctr["table_repairs"])),
            _row(pre + "prefill_retries",
                 derived=int(ctr["prefill_retries"])),
            _row(pre + "degraded", derived=int(ctr["degraded"])),
            _row(pre + "n_deadline", derived=rep["n_deadline"]),
            _row(pre + "n_rejected", derived=rep["n_rejected"]),
            _row(pre + "n_numerics", derived=rep["n_numerics"]),
            _row(pre + "n_cache_full", derived=rep["n_cache_full"]),
            _row(pre + "identical_to_ref", derived=identical),
        ]
    for r in rows:
        print(f"{r['op']},{r['us']:.1f},{r['derived']}")
    return rows


def olm_matmul_distributed_bench():
    """Mesh-sharded olm matmul over a forced 8-device host mesh.

    Runs in a fresh subprocess (benchmarks/distributed_worker.py) so the
    parent's jax runtime — typically initialized with the single real
    CPU device — is untouched: the worker forces
    --xla_force_host_platform_device_count=8 before its own jax import,
    which makes this bench deterministic on ANY host, 1-device CI
    runners included. The worker asserts the distributed contract
    in-bench (m/n partitions bit-identical to single-device per mode,
    k partition within olm_error_bound) and reports per-device local
    digit traffic (bytes_moved) + collective wire bytes (bytes_float);
    rows are diffed against results/baseline by
    tools/check_bench.py --only distributed.
    """
    import subprocess
    import sys

    from repro.configs.olm_array import MATMUL_MODES
    from repro.core.numerics import TRUNCATED_SPECS

    devices, size = 8, 64
    print(f"\n== olm_matmul_distributed: {size}^3 GEMM over "
          f"{devices} forced host devices ==")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.distributed_worker",
         "--devices", str(devices), "--size", str(size),
         "--widths", ",".join(str(n) for n in sorted(MATMUL_MODES)),
         "--trunc", ",".join(f"{n}:{p}"
                             for n, p in sorted(TRUNCATED_SPECS))],
        capture_output=True, text=True, env=env)
    sys.stderr.write(proc.stderr)
    if proc.returncode != 0:
        raise AssertionError(
            f"distributed worker failed (rc={proc.returncode}) — the "
            "sharded-vs-single-device contract did not hold; see stderr")
    rows = json.loads(proc.stdout)["rows"]
    for r in rows:
        print(f"{r['op']},{r['us']:.1f},{r['ulp']}")
    mn = [r for r in rows if r["op"].endswith(("/m", "/n"))]
    assert mn and all(r["ulp"] == 0.0 for r in mn)
    return rows


def pipeline_activity():
    """Fig. 7 reproduction: per-cycle live slices + measured switching."""
    from repro.core.pipeline import run_pipeline
    from repro.core.precision import OnlinePrecision
    rng = np.random.default_rng(1)
    n, k = 16, 16
    pairs = [([int(d) for d in rng.integers(-1, 2, n)],
              [int(d) for d in rng.integers(-1, 2, n)]) for _ in range(k)]
    full = run_pipeline(pairs, OnlinePrecision(n=n, truncated=False,
                                               tail_gating=False))
    red = run_pipeline(pairs, OnlinePrecision(n=n))
    act_save = 100 * (1 - sum(red.active_slices_per_cycle) /
                      sum(full.active_slices_per_cycle))
    flip_save = 100 * (1 - red.flips_total / full.flips_total)
    print("\n== Fig. 7: activity & measured switching (n=16, k=16) ==")
    print(f"slice-cycles: full {sum(full.active_slices_per_cycle)} "
          f"reduced {sum(red.active_slices_per_cycle)} ({act_save:.1f}% saved)")
    print(f"register flips: full {full.flips_total} reduced {red.flips_total} "
          f"({flip_save:.1f}% saved)")
    print(f"fig7/activity,0.0,{act_save:.2f}")
    print(f"fig7/flips,0.0,{flip_save:.2f}")
    return [_row("fig7/activity", n=n, k=k, derived=round(act_save, 2)),
            _row("fig7/flips", n=n, k=k, derived=round(flip_save, 2))]


def roofline_report():
    """Aggregate dry-run JSONs into the §Roofline table (if present)."""
    from pathlib import Path
    d = Path("results/dryrun")
    files = sorted(d.glob("*.json")) if d.exists() else []
    if not files:
        print("\n== Roofline: no dry-run artifacts found (run "
              "repro.launch.dryrun) ==")
        return []
    print("\n== Roofline terms from dry-run (seconds; dominant term) ==")
    print(f"{'cell':>52} {'compute':>9} {'memory':>9} {'collective':>11} "
          f"{'dominant':>12}")
    rows = []
    for f in files:
        r = json.loads(f.read_text())
        if r.get("skipped"):
            continue
        t = r["roofline"]
        name = f"{r['arch']}|{r['shape']}|{r['mesh']}"
        print(f"{name:>52} {t['compute_s']:>9.4f} {t['memory_s']:>9.4f} "
              f"{t['collective_s']:>11.4f} {t['dominant']:>12}")
        rows.append(_row(f"roofline/{name}", derived=t["dominant"]))
    return rows


BENCHES = {
    "table1": table1_area_power,
    "table2": table2_multiplier_comparison,
    "table3": table3_cycles,
    "error_profile": error_profile,
    "tpmm": tpmm_bench,
    "online_dot": online_dot_bench,
    "olm_matmul": olm_matmul_bench,
    "olm_matmul_fused": olm_matmul_fused_bench,
    "olm_matmul_truncated": olm_matmul_truncated_bench,
    "olm_matmul_distributed": olm_matmul_distributed_bench,
    "serve_replay": serve_replay_bench,
    "serve_faults": serve_faults_bench,
    "fig7": pipeline_activity,
    "roofline": roofline_report,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--json-dir", default=None,
                    help="write BENCH_<name>.json per bench into this dir")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    if args.json_dir:
        os.makedirs(args.json_dir, exist_ok=True)
    for name in names:
        rows = BENCHES[name]() or []
        if args.json_dir:
            path = os.path.join(args.json_dir, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump({"bench": name, "rows": rows}, f, indent=1)
            print(f"wrote {path} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
