#!/usr/bin/env python
"""olmlint — static kernel-contract & numerics analyzer CLI.

Two engines (src/repro/analysis/):

  kernels  abstract-jaxpr contract checks on every registered Pallas
           kernel body at every MATMUL_MODES width x representative
           tiling bucket, under both x64 settings; the symbolic int32
           non-overflow proof of the Fig. 7 / Eq. 8 truncation
           schedule; decode-window coverage of the autotuner's legal
           k_tile range; and the static VMEM footprint model (block-
           shape tables + lane working set vs the width-aware budget),
           including every committed results/tuning.json entry.
  ast      repo architecture rules over src/ (raw-dot confinement,
           scoped-x64-only, no transcendental calls in scale modules,
           no contractions inside the serving scheduler) with a
           committed suppression baseline
           (tools/olmlint_baseline.json).

Exit codes: 0 clean, 1 violations found, 2 usage/internal error.
Run via `make lint` (both engines) or `make lint-kernels`.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO_ROOT, "src"))

from repro.analysis import run_ast_lint, run_kernel_lint   # noqa: E402
from repro.analysis.ast_lint import DEFAULT_BASELINE_PATH  # noqa: E402
from repro.configs.olm_array import MATMUL_MODES           # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--engine", choices=("all", "kernels", "ast"),
                    default="all")
    ap.add_argument("--widths", default=None,
                    help="comma-separated subset of MATMUL_MODES widths "
                         "for the kernel engine (default: all registered)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE_PATH,
                    help="AST suppression baseline JSON")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record current AST findings as the new baseline "
                         "instead of failing on them")
    args = ap.parse_args(argv)

    widths = None
    if args.widths:
        try:
            widths = tuple(int(w) for w in args.widths.split(","))
        except ValueError:
            ap.error(f"--widths must be comma-separated ints: {args.widths!r}")
        bad = sorted(set(widths) - set(MATMUL_MODES))
        if bad:
            ap.error(f"unregistered widths {bad}; registered: "
                     f"{sorted(MATMUL_MODES)}")

    violations = []
    if args.engine in ("all", "kernels"):
        kv = run_kernel_lint(widths)
        violations.extend(kv)
        print(f"olmlint kernels: {len(kv)} violation(s) "
              f"[widths={','.join(str(w) for w in sorted(widths or MATMUL_MODES))}]")
    if args.engine in ("all", "ast"):
        if args.write_baseline:
            _, raw_keys, _ = run_ast_lint(baseline=set())
            payload = {"comment": "olmlint AST suppressions — grandfathered "
                                  "sites only; keys are rule::relpath::"
                                  "qualname, so moving or adding a call "
                                  "invalidates its entry",
                       "suppressions": sorted(set(raw_keys))}
            with open(args.baseline, "w") as f:
                json.dump(payload, f, indent=2)
                f.write("\n")
            print(f"olmlint ast: wrote {len(payload['suppressions'])} "
                  f"suppression(s) to {args.baseline}")
        else:
            av, _, unused = run_ast_lint(baseline=args.baseline)
            violations.extend(av)
            print(f"olmlint ast: {len(av)} violation(s)")
            for key in sorted(unused):
                print(f"  note: stale baseline suppression {key!r} "
                      "(site gone — prune it)")

    if violations:
        print(f"\nolmlint: FAIL — {len(violations)} violation(s):\n",
              file=sys.stderr)
        for v in violations:
            print(str(v), file=sys.stderr)
            print(file=sys.stderr)
        return 1
    print("olmlint: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
