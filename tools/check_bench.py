#!/usr/bin/env python
"""CI guard over the perf story: traffic, baselines, tuning cache.

Replaces the old inline-heredoc CI step with a checked-in, locally
runnable tool. Three independent checks (all on by default):

  traffic   — from results/bench/BENCH_olm_matmul_fused.json: for EVERY
              registered olm matmul mode (configs/olm_array.MATMUL_MODES,
              n = 8/16/24/32), the quantize-in-kernel path must move
              >= 4x fewer operand bytes than its host-quantize grid
              mate (n_bits x by construction — the documented floor),
              and no registered width may be missing from the bench (a
              silently narrowed sweep is itself a regression).
  baseline  — every committed seed under results/baseline/ must have a
              freshly generated mate under results/bench/ whose rows
              match: traffic columns (bytes_moved / bytes_float) and
              analytic `derived` values (reuse ratios, cut factors)
              exactly, error columns (ulp) within --tol relative; rows
              present in the seed may not disappear. Wall-clock (us) is
              never compared — too noisy for shared CI runners; the
              JSON artifacts track it.
  serving   — from results/bench/BENCH_serve_replay.json: the traffic-
              replay serving bench must cover its full row schema
              (scheduler-step latency percentiles, completion counts,
              KV residency for both layouts), every request must
              complete, and the paged layout's resident KV bytes must
              sit STRICTLY below the contiguous slots*max_len figure —
              the whole point of the paged cache. Scheduler-step
              latency rows are deterministic (eos-free replay on a
              virtual clock), so the baseline check also diffs them;
              serve_replay/* rows get `derived` compared within --tol
              (percentile interpolation emits floats) while KV byte
              columns stay exact. Wall-clock stays ungated in CI; set
              REPRO_REPLAY_WALLCLOCK=1 to additionally compare the
              tokens_per_step row's recorded wall time against the
              committed baseline within --wall-tol (opt-in: shared CI
              runners are too noisy — turn it on where hardware is
              stable).
  faults    — from results/bench/BENCH_serve_faults.json: the chaos
              bench (seeded fault injection through the serving engine,
              two seeds) must cover its full counter schema, every
              fault family must actually have fired on each seed, and
              the bench's resolution bookkeeping must balance: each
              injected NaN resolves to a finish_reason="numerics", each
              table corruption to a recorded repair, each transient
              prefill failure to a retry, and exhaustion to >= 1
              preemption. Row values are exact ints, so the baseline
              check doubles as a cross-run/cross-host determinism gate
              for the whole fault-recovery pipeline.
  tuning    — results/tuning.json must parse against the TuningCache
              schema, and for every cached entry the value
              `tiling="auto"` would actually serve (get_tiling on the
              entry's recorded shape) must re-pin k_tile to the kernel
              numerics default — the PR-4 invariant that a stale or
              hand-edited cache can adjust blocks (pure perf) but can
              never change model outputs. Truncated-mode entries carry
              "trunc" (the working precision p): their bucket key must
              end in t{p} — so truncated and full tiers of the same
              n_bits can never share an entry — and every precision-
              dependent check runs at p.
  distributed — from results/bench/BENCH_olm_matmul_distributed.json
              (the shard_map bench; its worker forces an 8-device host
              mesh, so the gate runs on 1-device CI too): every
              registered width plus the olm32t16 tier must carry rows
              for all three partitions; m/n rows must keep ulp = 0.0
              exactly — the bit-identity marker the worker asserts
              against single-device olm_matmul — with no collective
              bytes; k rows must stay within olm_error_bound
              (0 <= ulp <= 1, the consumed bound fraction), report the
              device count under `derived`, and carry a positive f32
              all-reduce byte figure.
  truncated — from results/bench/BENCH_olm_matmul_truncated.json: every
              registered olm{n}t{p} tier (numerics.TRUNCATED_SPECS)
              must be present, cut its digit operand bytes by >= p/n
              vs the same-width full mode, and keep its measured max
              error within the extended olm_error_bound (the bench's
              ulp column is the error/bound fraction).

Usage (CI runs it bare from the repo root after the bench smoke step):

  python tools/check_bench.py [--bench results/bench]
      [--baseline results/baseline] [--tuning results/tuning.json]
      [--tol 0.1] [--only traffic,baseline,tuning]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO_ROOT, "src"))

from repro.configs.olm_array import (MATMUL_MODES,                # noqa: E402
                                     TRUNCATED_SPECS)
from repro.kernels.online_dot.tuning import (TuningCache,         # noqa: E402
                                             bucket_key, get_tiling,
                                             max_k_tile, pinned_k_tile)

_BUCKET_KEY = re.compile(r"^m\d+n\d+k\d+b\d+(t\d+)?$")
_TUNING_REQUIRED = {"k_tile": int, "block_m": int, "block_n": int,
                    "source": str, "shape": list, "n_bits": int}


class CheckFailure(Exception):
    pass


def _load(path: str) -> dict:
    if not os.path.exists(path):
        raise CheckFailure(f"missing file: {path}")
    with open(path) as f:
        return json.load(f)


def check_traffic(bench_dir: str) -> None:
    """Fused-vs-host operand-byte floor, for every registered mode."""
    rows = _load(os.path.join(bench_dir,
                              "BENCH_olm_matmul_fused.json"))["rows"]
    host = {r["n"]: r["bytes_moved"] for r in rows
            if r["op"] == "olm_matmul_fused/grid-host"}
    fused = {r["n"]: r["bytes_moved"] for r in rows
             if r["op"] == "olm_matmul_fused/grid-fused"}
    missing = set(MATMUL_MODES) - (set(host) & set(fused))
    if missing:
        raise CheckFailure(
            f"olm_matmul_fused bench is missing registered widths "
            f"{sorted(missing)} (have host={sorted(host)}, "
            f"fused={sorted(fused)}): the sweep must cover every "
            "MATMUL_MODES entry")
    for n in sorted(fused):
        fb, hb = fused[n], host[n]
        if fb * 4 > hb:
            raise CheckFailure(
                f"n={n}: fused path moved {fb} B vs host {hb} B — "
                f"below the documented >= 4x cut")
        print(f"  traffic n={n}: fused {fb} B vs host {hb} B "
              f"({hb / fb:.0f}x cut) ok")


def _close(a, b, tol: float) -> bool:
    if a is None or b is None:
        return a == b
    if isinstance(a, str) or isinstance(b, str):
        return a == b
    return abs(a - b) <= tol * max(abs(a), abs(b)) + 1e-9


def check_baseline(bench_dir: str, baseline_dir: str, tol: float) -> None:
    """Fresh bench JSON vs the committed seeds, with tolerance."""
    seeds = sorted(f for f in os.listdir(baseline_dir)
                   if f.startswith("BENCH_") and f.endswith(".json"))
    if not seeds:
        raise CheckFailure(f"no BENCH_*.json seeds under {baseline_dir}")
    for name in seeds:
        want = {(r["op"], r["n"], r["k"]): r
                for r in _load(os.path.join(baseline_dir, name))["rows"]}
        got = {(r["op"], r["n"], r["k"]): r
               for r in _load(os.path.join(bench_dir, name))["rows"]}
        if missing := set(want) - set(got):
            raise CheckFailure(
                f"{name}: rows vanished vs the committed baseline: "
                f"{sorted(missing)} — coverage may not silently narrow")
        for key, w in sorted(want.items()):
            g = got[key]
            # exact: traffic columns and `derived` are analytic counts/
            # ratios — a single byte or ratio tick is a real regression.
            # Exception: serve_replay latency rows carry percentile-
            # interpolated floats in `derived`; those get the ulp-style
            # relative tolerance (KV byte columns stay exact).
            serving_row = str(key[0]).startswith("serve_replay/")
            for col in ("bytes_moved", "bytes_float", "derived"):
                if serving_row and col == "derived":
                    if not _close(w.get(col), g.get(col), tol):
                        raise CheckFailure(
                            f"{name} {key}: {col} {g.get(col)} vs baseline "
                            f"{w.get(col)} exceeds rel tol {tol} "
                            "(serving latency regression)")
                    continue
                if w.get(col) != g.get(col):
                    raise CheckFailure(
                        f"{name} {key}: {col} {g.get(col)} != baseline "
                        f"{w.get(col)} (traffic/structure regression)")
            # tolerant: measured error columns may wiggle across
            # backends (the bench's f64 reference keeps this small)
            if not _close(w.get("ulp"), g.get("ulp"), tol):
                raise CheckFailure(
                    f"{name} {key}: ulp {g.get('ulp')} vs baseline "
                    f"{w.get('ulp')} exceeds rel tol {tol}")
        print(f"  baseline {name}: {len(want)} rows match "
              f"(bytes/derived exact, ulp within {tol:.0%})")


_SERVING_REQUIRED_OPS = (
    "serve_replay/ttft_p50", "serve_replay/ttft_p99",
    "serve_replay/e2e_p50", "serve_replay/e2e_p99",
    "serve_replay/tokens_per_step", "serve_replay/completed",
    "serve_replay/cache_full", "serve_replay/prefill_compiles",
    "serve_replay/blocks_peak", "serve_replay/kv_paged",
    "serve_replay/kv_contig",
)


def check_serving(bench_dir: str, baseline_dir: str,
                  wall_tol: float) -> None:
    """Serving replay schema + the paged-residency invariant (+ the
    opt-in REPRO_REPLAY_WALLCLOCK=1 wall-clock gate)."""
    rows = {r["op"]: r
            for r in _load(os.path.join(bench_dir,
                                        "BENCH_serve_replay.json"))["rows"]}
    if missing := set(_SERVING_REQUIRED_OPS) - set(rows):
        raise CheckFailure(
            f"serve_replay bench is missing rows {sorted(missing)}: the "
            "serving schema may not silently narrow")
    for op in ("serve_replay/ttft_p50", "serve_replay/ttft_p99",
               "serve_replay/e2e_p50", "serve_replay/e2e_p99",
               "serve_replay/tokens_per_step"):
        d = rows[op]["derived"]
        if not isinstance(d, (int, float)) or not d >= 0:
            raise CheckFailure(f"{op}: derived must be a number >= 0, "
                               f"got {d!r}")
    if rows["serve_replay/ttft_p99"]["derived"] < \
            rows["serve_replay/ttft_p50"]["derived"]:
        raise CheckFailure("ttft p99 below p50 — percentiles are broken")
    n = rows["serve_replay/completed"]["derived"]
    if not isinstance(n, int) or n < 1:
        raise CheckFailure(f"completed must be a positive int, got {n!r}")
    paged = rows["serve_replay/kv_paged"]
    contig = rows["serve_replay/kv_contig"]
    for r, cols in ((paged, ("bytes_moved", "bytes_float")),
                    (contig, ("bytes_moved",))):
        for col in cols:
            if not isinstance(r.get(col), int) or r[col] <= 0:
                raise CheckFailure(
                    f"{r['op']}: {col} must be a positive int, "
                    f"got {r.get(col)!r}")
    if paged["bytes_moved"] >= contig["bytes_moved"]:
        raise CheckFailure(
            f"paged KV residency {paged['bytes_moved']} B is not strictly "
            f"below the contiguous slots*max_len figure "
            f"{contig['bytes_moved']} B — the paged cache saved nothing")
    print(f"  serving: {len(_SERVING_REQUIRED_OPS)} schema rows ok, "
          f"{n} requests completed, paged KV {paged['bytes_moved']} B < "
          f"contiguous {contig['bytes_moved']} B "
          f"({100 * paged['bytes_moved'] / contig['bytes_moved']:.1f}%)")
    # Opt-in wall-clock gate (ROADMAP serving item (d)): scheduler-step
    # rows are the CI gate; on stable hardware REPRO_REPLAY_WALLCLOCK=1
    # additionally holds the recorded wall time of the whole replay
    # (the `us` column of the tokens_per_step row) to the committed
    # baseline within --wall-tol relative.
    if os.environ.get("REPRO_REPLAY_WALLCLOCK") == "1":
        base = {r["op"]: r for r in _load(os.path.join(
            baseline_dir, "BENCH_serve_replay.json"))["rows"]}
        op = "serve_replay/tokens_per_step"
        want, got = base[op].get("us"), rows[op].get("us")
        if not want or not got:
            raise CheckFailure(
                f"{op}: wall-clock gate enabled but us column is "
                f"empty (baseline {want!r}, fresh {got!r})")
        if not _close(want, got, wall_tol):
            raise CheckFailure(
                f"{op}: wall {got:.0f} us vs baseline {want:.0f} us "
                f"exceeds rel tol {wall_tol} (wall-clock regression; "
                "unset REPRO_REPLAY_WALLCLOCK on noisy hosts)")
        print(f"  serving wall-clock: {got:.0f} us vs baseline "
              f"{want:.0f} us within {wall_tol:.0%} (opt-in gate)")


_FAULTS_COUNTER_OPS = (
    "completed", "steps_total", "injected_exhaust", "injected_corrupt",
    "injected_nan", "injected_prefill_fail", "preempted", "table_repairs",
    "prefill_retries", "degraded", "n_deadline", "n_rejected", "n_numerics",
    "n_cache_full", "identical_to_ref",
)
_FAULTS_SEEDS = (0, 1)


def check_faults(bench_dir: str) -> None:
    """Chaos-bench schema + fault-resolution bookkeeping, per seed."""
    rows = {r["op"]: r
            for r in _load(os.path.join(bench_dir,
                                        "BENCH_serve_faults.json"))["rows"]}
    for seed in _FAULTS_SEEDS:
        pre = f"serve_faults/s{seed}/"
        want = {pre + op for op in _FAULTS_COUNTER_OPS}
        if missing := want - set(rows):
            raise CheckFailure(
                f"serve_faults bench is missing rows {sorted(missing)}: "
                "the chaos schema may not silently narrow")
        v = {op: rows[pre + op]["derived"] for op in _FAULTS_COUNTER_OPS}
        for op, d in v.items():
            if not isinstance(d, int) or d < 0:
                raise CheckFailure(
                    f"{pre}{op}: derived must be an int >= 0, got {d!r}")
        for fam in ("injected_exhaust", "injected_corrupt", "injected_nan",
                    "injected_prefill_fail"):
            if v[fam] < 1:
                raise CheckFailure(
                    f"seed {seed}: {fam} = 0 — every fault family must "
                    "actually fire for the chaos gate to mean anything")
        # every injected fault resolves to an explicit finish or a
        # recorded recovery (the bench asserts the token-level side)
        balances = (("injected_nan", "n_numerics"),
                    ("injected_corrupt", "table_repairs"),
                    ("injected_prefill_fail", "prefill_retries"))
        for inj, res in balances:
            if v[inj] != v[res]:
                raise CheckFailure(
                    f"seed {seed}: {inj} = {v[inj]} but {res} = {v[res]} "
                    "— an injected fault did not resolve explicitly")
        if v["preempted"] < 1:
            raise CheckFailure(
                f"seed {seed}: block exhaustion fired but preempted = 0 "
                "— preemption-with-recompute never engaged")
        if not 1 <= v["identical_to_ref"] <= v["completed"]:
            raise CheckFailure(
                f"seed {seed}: identical_to_ref = {v['identical_to_ref']} "
                f"outside [1, completed={v['completed']}]")
        print(f"  faults seed {seed}: {v['completed']} resolved "
              f"({v['identical_to_ref']} identical to fault-free), "
              f"injected e/c/n/p = {v['injected_exhaust']}/"
              f"{v['injected_corrupt']}/{v['injected_nan']}/"
              f"{v['injected_prefill_fail']}, preempted {v['preempted']}, "
              f"degraded {v['degraded']} — bookkeeping balances")


def check_truncated(bench_dir: str) -> None:
    """olm{n}t{p} acceptance gate: every registered truncated spec must
    appear in BENCH_olm_matmul_truncated.json, its digit operand bytes
    must be cut by >= p/n vs the same-width full mode, and its measured
    max error must sit within the extended olm_error_bound (the bench
    stores ulp as the error/bound fraction)."""
    rows = _load(os.path.join(
        bench_dir, "BENCH_olm_matmul_truncated.json"))["rows"]
    full = {r["n"]: r for r in rows
            if r["op"] == "olm_matmul_truncated/full"}
    trunc = {(r["n"], int(r["op"].rsplit("/t", 1)[1])): r for r in rows
             if re.fullmatch(r"olm_matmul_truncated/t\d+", r["op"])}
    if missing := set(TRUNCATED_SPECS) - set(trunc):
        raise CheckFailure(
            f"truncated bench is missing registered tiers "
            f"{sorted(missing)}: the sweep must cover every "
            "TRUNCATED_SPECS entry")
    for (n, p), r in sorted(trunc.items()):
        if n not in full:
            raise CheckFailure(
                f"olm{n}t{p}: no same-width full-mode row to compare "
                "against")
        tb, fb = r["bytes_moved"], full[n]["bytes_moved"]
        if tb * n > fb * p:
            raise CheckFailure(
                f"olm{n}t{p}: digit operand bytes {tb} vs full {fb} — "
                f"below the documented >= {p}/{n} cut")
        if not isinstance(r["ulp"], (int, float)) or r["ulp"] > 1.0:
            raise CheckFailure(
                f"olm{n}t{p}: error/bound fraction {r['ulp']!r} exceeds "
                "1.0 — outside the extended olm_error_bound")
        print(f"  truncated olm{n}t{p}: {tb} B vs full {fb} B "
              f"({fb / tb:.2f}x >= {n}/{p} cut), err/bound "
              f"{r['ulp']:.3f} ok")


def check_distributed(bench_dir: str) -> None:
    """Sharded-GEMM acceptance gate: for every registered width and the
    olm32t16 truncated tier, the m/n partitions must be bit-identical to
    single-device (ulp stored as exactly 0.0, no wire bytes) and the k
    partition's psum'd error must sit within olm_error_bound (ulp is the
    consumed bound fraction) over the worker's forced 8-device mesh."""
    rows = _load(os.path.join(
        bench_dir, "BENCH_olm_matmul_distributed.json"))["rows"]
    by_op = {r["op"]: r for r in rows}
    labels = [f"olm{n}" for n in sorted(MATMUL_MODES)] + [
        f"olm{n}t{p}" for n, p in sorted(TRUNCATED_SPECS)]
    want = {f"olm_matmul_distributed/{lab}/{part}"
            for lab in labels for part in ("m", "n", "k")}
    if missing := want - set(by_op):
        raise CheckFailure(
            f"distributed bench is missing rows {sorted(missing)}: the "
            "sharded sweep must cover every registered mode x partition")
    for lab in labels:
        devices = None
        for part in ("m", "n", "k"):
            r = by_op[f"olm_matmul_distributed/{lab}/{part}"]
            if not isinstance(r.get("bytes_moved"), int) or \
                    r["bytes_moved"] <= 0:
                raise CheckFailure(
                    f"{r['op']}: bytes_moved must be a positive int "
                    f"(per-device local digit traffic), "
                    f"got {r.get('bytes_moved')!r}")
            if part in ("m", "n"):
                # ulp == 0.0 is the worker's bit-identity marker, not a
                # measured error — any nonzero value means a shard
                # diverged from single-device olm_matmul.
                if r["ulp"] != 0.0 or r["derived"] != 1:
                    raise CheckFailure(
                        f"{r['op']}: expected bit-identity marker "
                        f"(ulp=0.0, derived=1), got ulp={r['ulp']!r} "
                        f"derived={r['derived']!r}")
                if r.get("bytes_float") != 0:
                    raise CheckFailure(
                        f"{r['op']}: output-sharded partitions move no "
                        f"collective bytes, got {r.get('bytes_float')!r}")
            else:
                if not isinstance(r["ulp"], (int, float)) or \
                        not 0 <= r["ulp"] <= 1.0:
                    raise CheckFailure(
                        f"{r['op']}: error/bound fraction {r['ulp']!r} "
                        "outside [0, 1] — the psum'd contraction left "
                        "olm_error_bound")
                devices = r["derived"]
                if not isinstance(devices, int) or devices < 2:
                    raise CheckFailure(
                        f"{r['op']}: derived must record the mesh device "
                        f"count (>= 2), got {devices!r}")
                if not isinstance(r.get("bytes_float"), int) or \
                        r["bytes_float"] <= 0:
                    raise CheckFailure(
                        f"{r['op']}: k partition must report positive f32 "
                        f"all-reduce bytes, got {r.get('bytes_float')!r}")
        k = by_op[f"olm_matmul_distributed/{lab}/k"]
        print(f"  distributed {lab}: m/n bit-identical over "
              f"{devices} devices, k err/bound {k['ulp']:.3f} "
              f"(wire {k['bytes_float']} B) ok")


def check_tuning(tuning_path: str) -> None:
    """Schema + the k_tile-re-pin numerics invariant, per cached entry."""
    data = _load(tuning_path)
    if set(data) != {"entries"} or not isinstance(data["entries"], dict):
        raise CheckFailure(
            f"{tuning_path}: top level must be exactly {{'entries': "
            f"{{...}}}}, got keys {sorted(data)}")
    cache = TuningCache(tuning_path)   # one parse, shared by every lookup
    for key, e in sorted(data["entries"].items()):
        if not _BUCKET_KEY.match(key):
            raise CheckFailure(f"{tuning_path}: malformed bucket key {key!r}")
        for field, typ in _TUNING_REQUIRED.items():
            if not isinstance(e.get(field), typ):
                raise CheckFailure(
                    f"{tuning_path} {key}: field {field!r} missing or not "
                    f"{typ.__name__}: {e.get(field)!r}")
        if e["source"] not in ("measured", "heuristic"):
            raise CheckFailure(
                f"{tuning_path} {key}: unknown source {e['source']!r}")
        if len(e["shape"]) != 3 or not all(
                isinstance(v, int) and v >= 1 for v in e["shape"]):
            raise CheckFailure(
                f"{tuning_path} {key}: shape must be three ints >= 1, "
                f"got {e['shape']}")
        if min(e["block_m"], e["block_n"], e["k_tile"]) < 1:
            raise CheckFailure(f"{tuning_path} {key}: non-positive tiling")
        # Truncated-mode entries record their working precision under
        # "trunc"; every precision-dependent check below runs at the
        # WORK digits, and the bucket key must carry the matching t{p}
        # suffix — a truncated entry that could answer a full-mode
        # lookup (or vice versa) would serve the wrong tier's tiling.
        trunc = e.get("trunc")
        if trunc is not None and (not isinstance(trunc, int)
                                  or not 0 < trunc < e["n_bits"]):
            raise CheckFailure(
                f"{tuning_path} {key}: trunc must be an int in "
                f"(0, n_bits={e['n_bits']}), got {trunc!r}")
        M, N, K = e["shape"]
        want_key = bucket_key(M, N, K, e["n_bits"], trunc)
        if key != want_key:
            raise CheckFailure(
                f"{tuning_path} {key}: key does not match its entry "
                f"(shape/n_bits/trunc rebucket to {want_key!r}) — "
                "truncated and full tiers may not share entries")
        work = trunc if trunc is not None else e["n_bits"]
        # Cached k_tile must stay inside the work width's exact decode
        # window (work + 2*ceil(log2 k_tile) <= the per-dtype window):
        # a hand-edited or stale entry past max_k_tile would decode an
        # over-long digit stream and silently lose bit-exactness.
        if e["k_tile"] > max_k_tile(work):
            raise CheckFailure(
                f"{tuning_path} {key}: k_tile {e['k_tile']} exceeds "
                f"max_k_tile({work}) = {max_k_tile(work)} — "
                "the stream would leave the exact decode window")
        # The invariant: whatever k_tile the entry stores, what
        # tiling="auto" serves for this entry's shape must be the
        # kernel numerics default (tuning.pinned_k_tile — the same
        # formula the auto path itself uses, so the guard can't drift).
        served = get_tiling(M, N, K, e["n_bits"], cache, trunc=trunc)
        pinned = pinned_k_tile(K, work)
        if served["k_tile"] != pinned:
            raise CheckFailure(
                f"{tuning_path} {key}: auto would serve k_tile="
                f"{served['k_tile']}, numerics default is {pinned} — "
                "the re-pin invariant is broken")
    print(f"  tuning {tuning_path}: {len(data['entries'])} entries valid, "
          "k_tile re-pin invariant holds")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", default=os.path.join(_REPO_ROOT, "results",
                                                    "bench"))
    ap.add_argument("--baseline", default=os.path.join(_REPO_ROOT, "results",
                                                       "baseline"))
    ap.add_argument("--tuning", default=os.path.join(_REPO_ROOT, "results",
                                                     "tuning.json"))
    ap.add_argument("--tol", type=float, default=0.1,
                    help="relative tolerance for derived/ulp columns")
    ap.add_argument("--wall-tol", type=float, default=0.5,
                    help="relative tolerance for the opt-in "
                         "REPRO_REPLAY_WALLCLOCK=1 wall-clock gate")
    ap.add_argument("--only",
                    default="traffic,baseline,serving,tuning,truncated,"
                            "faults,distributed",
                    help="comma-separated subset of checks to run")
    args = ap.parse_args(argv)
    checks = {
        "traffic": lambda: check_traffic(args.bench),
        "baseline": lambda: check_baseline(args.bench, args.baseline,
                                           args.tol),
        "serving": lambda: check_serving(args.bench, args.baseline,
                                         args.wall_tol),
        "tuning": lambda: check_tuning(args.tuning),
        "truncated": lambda: check_truncated(args.bench),
        "faults": lambda: check_faults(args.bench),
        "distributed": lambda: check_distributed(args.bench),
    }
    failed = False
    for name in args.only.split(","):
        name = name.strip()
        if name not in checks:
            print(f"unknown check {name!r}; have {sorted(checks)}")
            return 2
        print(f"check_bench: {name}")
        try:
            checks[name]()
        except CheckFailure as e:
            print(f"  FAIL: {e}")
            failed = True
    if failed:
        return 1
    print("check_bench: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
